#!/usr/bin/env python
"""Embedding-lineage audit gate (stdlib-only; CI `drift-gate` job).

Reads a lineage report — either a raw ``LineageReport.to_dict()`` manifest
or any bench JSON embedding one under a ``"lineage"`` key (e.g.
``BENCH_governor.json``) — and checks the store's rows all come from ONE
embedding space, the horadus-style audit: after a cutover there must be no
rows still embedded with the old model and no rows whose source space is
unknown.

    python tools/check_lineage.py experiments/bench/BENCH_governor.json \
        --fail-on-mixed [--expect-space v2] [--key lineage_mid]

Without ``--fail-on-mixed`` the report is printed but mixed state only
warns (exit 0) — the mid-migration state is legitimate while an upgrade
is in flight. Exit codes: 0 clean, 1 mixed/missing (with the flag),
2 malformed input.
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED = ("rows_by_space", "missing", "total")


def load_report(path: str, key: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    # raw manifest, or a bench JSON wrapping one under `key`
    report = payload if all(k in payload for k in REQUIRED) else payload.get(key)
    if not isinstance(report, dict) or not all(k in report for k in REQUIRED):
        raise ValueError(
            f"{path}: neither a lineage manifest nor a JSON with a "
            f"{key!r} manifest (need keys {REQUIRED})"
        )
    return report


def audit(report: dict, expect_space: str | None) -> list[str]:
    """Returns the list of violations (empty = single-space store)."""
    problems: list[str] = []
    spaces = {k: int(v) for k, v in report["rows_by_space"].items() if int(v)}
    missing = int(report["missing"])
    if len(spaces) > 1:
        problems.append(f"rows from {len(spaces)} spaces: {spaces}")
    if missing > 0:
        problems.append(f"{missing} rows with unknown lineage")
    if expect_space is not None and set(spaces) != {expect_space}:
        problems.append(
            f"expected every row in {expect_space!r}, got {spaces}"
        )
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="lineage manifest or bench JSON path")
    ap.add_argument("--key", default="lineage",
                    help="key holding the manifest inside a bench JSON")
    ap.add_argument("--fail-on-mixed", action="store_true",
                    help="exit 1 on mixed/missing lineage (the post-cutover "
                         "CI gate); default only warns")
    ap.add_argument("--expect-space", default=None,
                    help="additionally require every row in THIS space")
    args = ap.parse_args(argv)

    try:
        report = load_report(args.report, args.key)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check_lineage: {e}", file=sys.stderr)
        return 2

    total = report["total"]
    frac = report.get("mixed_fraction", "n/a")
    print(f"lineage: {total} rows, by space {report['rows_by_space']}, "
          f"missing {report['missing']}, mixed_fraction {frac}")
    problems = audit(report, args.expect_space)
    if not problems:
        print("lineage OK: single-space store")
        return 0
    for p in problems:
        print(f"lineage {'FAIL' if args.fail_on_mixed else 'WARN'}: {p}")
    return 1 if args.fail_on_mixed else 0


if __name__ == "__main__":
    sys.exit(main())
