#!/usr/bin/env python
"""Docs consistency gate (stdlib-only; CI `docs` job).

Two checks over README.md + docs/*.md:

1. Intra-repo markdown links ``[text](target)`` resolve: any target that is
   not an external URL or a pure #anchor must name a file (or directory)
   that exists, relative to the file containing the link. In-page and
   cross-page #anchors are checked against the target's headings.

2. Code references in docs/*.md of the form ``path/to/file.py:symbol``
   (backticked, path relative to the repo root) name a real file AND a
   symbol that actually occurs in it — docs rot loudly, not silently,
   when code moves.

Exit status: number of failures (0 = green).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
# `path:symbol` — a repo-relative source path plus a python identifier
# (dotted attribute access allowed: Class.method)
CODE_REF_RE = re.compile(
    r"`([A-Za-z0-9_\-./]+\.(?:py|yml|md)):([A-Za-z_][A-Za-z0-9_.]*)`"
)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash spaces."""
    text = re.sub(r"[`*]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set[str]:
    return {slugify(h) for h in HEADING_RE.findall(path.read_text())}


def check_links(md: pathlib.Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                errors.append(
                    f"{md.relative_to(ROOT)}: missing anchor "
                    f"#{anchor} in {dest.relative_to(ROOT)}"
                )
    return errors


def check_code_refs(md: pathlib.Path) -> list[str]:
    errors = []
    for path_str, symbol in CODE_REF_RE.findall(md.read_text()):
        src = ROOT / path_str
        if not src.exists():
            errors.append(
                f"{md.relative_to(ROOT)}: code ref names missing file "
                f"{path_str}"
            )
            continue
        text = src.read_text()
        # every dotted component must occur as a word in the file
        missing = [
            part for part in symbol.split(".")
            if not re.search(rf"\b{re.escape(part)}\b", text)
        ]
        if missing:
            errors.append(
                f"{md.relative_to(ROOT)}: code ref {path_str}:{symbol} — "
                f"symbol(s) {missing} not found in {path_str}"
            )
    return errors


def main() -> int:
    docs = sorted((ROOT / "docs").glob("*.md"))
    if not docs:
        print("FAIL: docs/ contains no markdown pages")
        return 1
    errors: list[str] = []
    for md in [ROOT / "README.md", *docs]:
        errors.extend(check_links(md))
    for md in docs:
        errors.extend(check_code_refs(md))
    for e in errors:
        print(f"FAIL: {e}")
    n_refs = sum(len(CODE_REF_RE.findall(p.read_text())) for p in docs)
    print(
        f"checked {len(docs) + 1} pages, {n_refs} code refs: "
        f"{len(errors)} failure(s)"
    )
    return min(len(errors), 125)


if __name__ == "__main__":
    sys.exit(main())
