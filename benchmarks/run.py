"""Benchmark orchestrator — one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--seeds N]
                                            [--only t1,t3,...]

Prints ``name,us_per_call,derived`` CSV (harness contract) and writes JSON
artifacts to experiments/bench/. Suites:

    t1      Table 1  — text upgrades (3 corpora, OP/LA/MLP ± DSM)
    t2      Table 2  — image upgrade, rectangular 512→768
    t3      Table 3  — upgrade-strategy comparison
    t4      Table 4  — drastic drift (GloVe→MPNet analogue)
    fig1    Figure 1 — ARR vs N_p
    online  §5.6     — continuous online adaptation (24 ticks)
    hetero  App A.4  — heterogeneous drift, multi-adapter routing
    a1t5    App A.1 + Table 5 — memory / latency / scale projection
    ann     §4       — ANN back-end recall/latency knob (nprobe)
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks import (
    ablations,
    ann_backend,
    fig1_training_size,
    heterogeneous,
    memory_latency,
    online_adaptation,
    t1_text,
    t2_image,
    t3_strategies,
    t4_severe,
)
from benchmarks.common import DEFAULT, FULL, QUICK

SUITES = {
    "t1": t1_text.run,
    "t2": t2_image.run,
    "t3": t3_strategies.run,
    "t4": t4_severe.run,
    "fig1": fig1_training_size.run,
    "online": online_adaptation.run,
    "hetero": heterogeneous.run,
    "a1t5": memory_latency.run,
    "ann": ann_backend.run,
    "abl": ablations.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=None)
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()

    scale = QUICK if args.quick else FULL if args.full else DEFAULT
    if args.seeds is not None:
        scale = dataclasses.replace(scale, seeds=args.seeds)
    names = list(SUITES) if not args.only else args.only.split(",")

    print("name,us_per_call,derived")
    t_start = time.perf_counter()
    for name in names:
        if name not in SUITES:
            raise SystemExit(f"unknown suite {name!r}; have {list(SUITES)}")
        t0 = time.perf_counter()
        SUITES[name](scale)
        print(f"# suite {name} done in {time.perf_counter()-t0:.1f}s",
              flush=True)
    print(f"# all suites done in {time.perf_counter()-t_start:.1f}s")


if __name__ == "__main__":
    main()
