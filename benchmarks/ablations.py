"""Ablations — design-choice studies from the paper + beyond-paper variants.

  * DSM on/off for LA and MLP (paper §3: DSM adds +0.005..0.015 ARR) and
    post-hoc DSM for OP (paper: <0.005, omitted by default).
  * ℓ2 pre-normalization of pair embeddings before fitting (paper Fig. 5:
    pre-normalized fits are slightly better and more stable).
  * BEYOND-PAPER: Procrustes warm start for LA/MLP (closes the from-scratch
    convergence gap under strong rotation — EXPERIMENTS.md §Tables).
"""
from __future__ import annotations

import jax

from repro.core import DriftAdapter, FitConfig
from repro.data.drift import MILD_TEXT
from benchmarks.common import Scale, build_scenario, emit, eval_adapter, save_json


def run(scale: Scale) -> dict:
    scen = build_scenario("abl", MILD_TEXT, scale, corpus_seed=0, pair_seed=5)
    out: dict = {}

    def fit_eval(tag, kind, **kw):
        ad = DriftAdapter.fit(
            scen.pairs_b, scen.pairs_a, kind=kind,
            config=FitConfig(kind=kind, **kw),
        )
        r = eval_adapter(scen, ad)
        out[tag] = r["r10_arr"]
        emit(f"abl.{tag}.r10_arr", ad.fit_info.fit_seconds * 1e6,
             round(r["r10_arr"], 4))
        return r["r10_arr"]

    # --- DSM ---------------------------------------------------------------
    for kind in ("la", "mlp"):
        with_dsm = fit_eval(f"{kind}_dsm", kind, use_dsm=True)
        without = fit_eval(f"{kind}_nodsm", kind, use_dsm=False)
        out[f"{kind}_dsm_gain"] = round(with_dsm - without, 4)
    fit_eval("op_nodsm", "op", use_dsm=False)
    fit_eval("op_dsm_posthoc", "op", use_dsm=True)

    # --- pre-normalization (Fig. 5) -----------------------------------------
    # simulate un-normalized embeddings: per-item lognormal scale jitter
    key = jax.random.PRNGKey(3)
    import jax.numpy as jnp

    scales_b = jnp.exp(0.3 * jax.random.normal(key, (scen.pairs_b.shape[0], 1)))
    scales_a = jnp.exp(0.3 * jax.random.normal(
        jax.random.fold_in(key, 1), (scen.pairs_a.shape[0], 1)
    ))
    ad_raw = DriftAdapter.fit(
        scen.pairs_b * scales_b, scen.pairs_a * scales_a, kind="mlp",
        config=FitConfig(kind="mlp"),
    )
    r = eval_adapter(scen, ad_raw)
    out["mlp_unnormalized_pairs"] = r["r10_arr"]
    emit("abl.mlp_unnormalized_pairs.r10_arr", 0.0, round(r["r10_arr"], 4))

    # --- beyond-paper: Procrustes warm start --------------------------------
    for kind in ("la", "mlp"):
        fit_eval(f"{kind}_warmstart", kind, use_dsm=True,
                 procrustes_warm_start=True)

    save_json("ablations", out)
    return out
