"""ANN back-end characterization — the TPU-native analogue of the paper's
FAISS-HNSW ef_search setting (§4).

Recall/latency trade-off of the IVF-Flat index as a function of nprobe,
with the exact flat scan as the reference point, searched with
adapter-mapped queries (the production query path). Shows nprobe plays
ef_search's role: the paper's ef_search=50 ≈ our nprobe≈8 operating point.
"""
from __future__ import annotations

import jax

from repro.ann import build_ivf, flat_search_jnp, ivf_search, recall_at_k
from repro.core import DriftAdapter, FitConfig
from repro.data.drift import MILD_TEXT
from benchmarks.common import Scale, build_scenario, emit, save_json, time_per_call_us

NPROBES = (1, 2, 4, 8, 16, 32)


def run(scale: Scale) -> dict:
    n = min(scale.n_items, 100_000)
    scen = build_scenario(
        "ann", MILD_TEXT,
        Scale(n_items=n, n_queries=min(scale.n_queries, 500),
              n_pairs=scale.n_pairs),
        corpus_seed=0, pair_seed=5,
    )
    adapter = DriftAdapter.fit(
        scen.pairs_b, scen.pairs_a, kind="mlp",
        config=FitConfig(kind="mlp", use_dsm=True),
    )
    q = adapter.apply(scen.q_new)
    _, exact_ids = flat_search_jnp(scen.corpus_old, q, k=10)

    index = build_ivf(
        jax.random.PRNGKey(0), scen.corpus_old,
        n_cells=max(64, n // 400), spill_factor=3.0,
    )
    out = {"flat_exact_arr": float(recall_at_k(exact_ids, scen.gt))}
    emit("ann.flat.r10_arr", 0.0, round(out["flat_exact_arr"], 4))
    for nprobe in NPROBES:
        search = jax.jit(
            lambda qq, np_=nprobe: ivf_search(index, qq, k=10, nprobe=np_)
        )
        _, ids = search(q)
        arr = float(recall_at_k(ids, scen.gt))
        vs_exact = float(recall_at_k(ids, exact_ids))
        us = time_per_call_us(search, q, per_call_items=q.shape[0], iters=3)
        out[f"nprobe_{nprobe}"] = {
            "r10_arr": arr, "recall_vs_exact": vs_exact, "us_per_query": us
        }
        emit(f"ann.ivf.nprobe_{nprobe}.r10_arr", us, round(arr, 4))
    save_json("ann_backend", out)
    return out
