"""Appendix A.4 — heterogeneous drift: global vs domain-routed adapters.

Half the clusters drift through a (mild) affine map, half through a strong
nonlinear warp. A single global MLP averages the two regimes; two
domain-specific MLPs routed by item metadata (cluster parity) recover most
of the gap — the paper's 0.85 → 0.94 result, realized with MultiAdapter.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.ann import flat_search_jnp, recall_at_k
from repro.core import DriftAdapter, FitConfig, MultiAdapter
from repro.data import CorpusConfig, make_corpus, make_queries, make_drift
from repro.data.drift import DriftConfig
from benchmarks.common import Scale, emit, save_json

# Two drifts that are each individually recoverable (mild, like Table 1)
# but structurally DIFFERENT (independent rotations/scales/warps): a single
# global adapter must average two incompatible maps — that averaging, not
# any per-domain ceiling, is what the paper's A.4 isolates.
AFFINE = DriftConfig(d_old=768, d_new=768, rotation_rank=64,
                     rotation_theta=0.35, scale_sigma=0.02,
                     nonlinear_alpha=0.0, noise_sigma=0.002, seed=31)
WARPED = DriftConfig(d_old=768, d_new=768, rotation_rank=64,
                     rotation_theta=0.70, scale_sigma=0.06,
                     nonlinear_alpha=0.10, nonlinear_smoothness=1.5,
                     noise_sigma=0.003, seed=37)


def run(scale: Scale) -> dict:
    n = min(scale.n_items, 100_000)
    ccfg = CorpusConfig(n_items=n, dim=768, n_clusters=max(200, n // 150),
                        concentration=0.4, spectrum_beta=1.0, seed=3)
    corpus_old, clusters = make_corpus(ccfg)
    q_old, q_clusters = make_queries(ccfg, scale.n_queries)
    t_affine, t_warp = make_drift(AFFINE), make_drift(WARPED)

    domain = (clusters % 2).astype(bool)            # metadata routing key
    q_domain = (q_clusters % 2).astype(bool)

    # Separate the domains on the sphere (as real DBpedia class groups are):
    # without this, anisotropic clusters overlap so heavily that top-10 sets
    # cross domains and the two drifts scramble CROSS-domain geometry — a
    # ceiling no adapter (global or routed) can recover. The paper's domains
    # are semantically disjoint classes; we mirror that.
    sep = jax.random.normal(jax.random.PRNGKey(77), (768,))
    sep = 0.8 * sep / jnp.linalg.norm(sep)

    def separate(x, dom):
        shifted = x + jnp.where(dom[:, None], sep, -sep)
        return shifted / jnp.linalg.norm(shifted, axis=1, keepdims=True)

    corpus_old = separate(corpus_old, jnp.asarray(domain))
    q_old = separate(q_old, jnp.asarray(q_domain))
    corpus_new = jnp.where(
        domain[:, None], t_warp(corpus_old, 0), t_affine(corpus_old, 0)
    )
    q_new = jnp.where(
        q_domain[:, None], t_warp(q_old, 1), t_affine(q_old, 1)
    )
    _, gt = flat_search_jnp(corpus_new, q_new, k=10)

    key = jax.random.PRNGKey(5)
    idx = jax.random.choice(key, n, (scale.n_pairs,), replace=False)
    cfg = FitConfig(kind="mlp", use_dsm=True)

    # global adapter on a random mixed sample
    global_ad = DriftAdapter.fit(corpus_new[idx], corpus_old[idx], config=cfg)
    _, ids_g = flat_search_jnp(corpus_old, global_ad.apply(q_new), k=10)
    arr_global = float(recall_at_k(ids_g, gt))

    # two domain adapters, routed by metadata
    dom_idx = jnp.asarray(domain)[idx]
    adapters = []
    for d_val in (False, True):
        sel = idx[dom_idx == d_val]
        adapters.append(
            DriftAdapter.fit(corpus_new[sel], corpus_old[sel], config=cfg)
        )
    multi = MultiAdapter.from_adapters(adapters)
    q_routed = multi.apply(q_new, jnp.asarray(q_domain).astype(jnp.int32))
    _, ids_r = flat_search_jnp(corpus_old, q_routed, k=10)
    arr_routed = float(recall_at_k(ids_r, gt))

    out = {"global_mlp": arr_global, "routed_mlp": arr_routed}
    emit("a4.heterogeneous.global_mlp.r10_arr", 0.0, round(arr_global, 4))
    emit("a4.heterogeneous.routed_mlp.r10_arr", 0.0, round(arr_routed, 4))
    save_json("heterogeneous", out)
    return out
