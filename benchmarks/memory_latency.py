"""Appendix A.1 + Table 5 — adapter memory/latency details and the
large-scale projection.

Memory is EXACT (bytes of the fitted parameter pytrees). Latency: CPU
measured (batch-amortized µs/query) + TPU roofline projection. Table 5's
re-embed / index-build columns are modeled with the same reference rates
the paper uses; the adapter columns are measured here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import DriftAdapter, FitConfig
from repro.launch.roofline import PEAK_FLOPS
from benchmarks.common import Scale, emit, save_json, time_per_call_us


def run(scale: Scale) -> dict:
    d = 768
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (20_000, d))
    b = b / jnp.linalg.norm(b, axis=1, keepdims=True)
    r = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
    a = b @ r.T

    out: dict = {"adapters": {}}
    fit_seconds_mlp = None
    for kind, dsm in (("op", False), ("la", True), ("mlp", True)):
        ad = DriftAdapter.fit(
            b, a, kind=kind,
            config=FitConfig(kind=kind, use_dsm=dsm, max_epochs=10),
        )
        apply_jit = jax.jit(lambda q, _ad=ad: _ad.apply(q))
        batch = b[:1024]
        us_cpu = time_per_call_us(apply_jit, batch, per_call_items=1024)
        us_tpu = ad.flops_per_query / PEAK_FLOPS * 1e6
        row = {
            "param_bytes": ad.param_bytes,
            "param_mb": round(ad.param_bytes / 2**20, 3),
            "flops_per_query": ad.flops_per_query,
            "us_per_query_cpu": round(us_cpu, 2),
            "us_per_query_tpu_roofline": round(us_tpu, 5),
            "fit_seconds": round(ad.fit_info.fit_seconds, 2),
        }
        out["adapters"][kind] = row
        if kind == "mlp":
            fit_seconds_mlp = ad.fit_info.fit_seconds
        emit(f"a1.{kind}.apply_us_cpu", us_cpu, ad.param_bytes)

    # Table 5 projection — adapter columns measured, re-embed/build modeled
    embed_rate = 400.0          # items / GPU-second (A100, d=768 encoder)
    hnsw_ms = {1e6: 0.5, 1e8: 5.0, 1e9: 15.0}
    t5 = {}
    for n in (1e6, 1e8, 1e9):
        gpu_hr = n / embed_rate / 3600
        t5[f"{int(n):,}"] = {
            "reembed_gpu_hours_model": round(gpu_hr, 1),
            "adapter_fit_seconds_measured": round(fit_seconds_mlp, 1),
            "adapter_added_us": out["adapters"]["mlp"]["us_per_query_cpu"],
            "query_ms_before": hnsw_ms[n],
            "query_ms_after": round(
                hnsw_ms[n]
                + out["adapters"]["mlp"]["us_per_query_cpu"] / 1000, 4
            ),
        }
        emit(f"t5.scale_{int(n)}.query_ms_after", 0.0,
             t5[f"{int(n):,}"]["query_ms_after"])
    out["t5_projection"] = t5
    save_json("memory_latency", out)
    return out
