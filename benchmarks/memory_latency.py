"""Appendix A.1 + Table 5 — adapter memory/latency details and the
large-scale projection, plus the fused-vs-unfused bridged query path.

Memory is EXACT (bytes of the fitted parameter pytrees). Latency: CPU
measured (batch-amortized µs/query) + TPU roofline projection. Table 5's
re-embed / index-build columns are modeled with the same reference rates
the paper uses; the adapter columns are measured here.

The fused section times the one-pass bridged search (the engine's
linear/MLP-stage flat launch: adapter + scan + top-k in one pallas_call)
against the production two-launch path (kernels/adapter_apply then the
identity-stage scan, transformed queries round-tripping HBM in between),
asserts exact score/id parity against the jnp reference, and reports the
HBM bytes each path moves. The engine section (--engine-only) A/Bs the
packed dual-query mixed scan (ONE matmul per corpus block, post-matmul
bitmap select) against the two-matmul variant, parity-gated bit-exact.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import build_ivf, ivf_search
from repro.core import DriftAdapter, FitConfig
from repro.kernels.adapter_apply.ops import adapter_apply_fused
from repro.kernels.engine import (
    fused_bridged_search,
    mixed_bridged_search,
    topk_scan,
)
from repro.kernels.fused_search.ref import fused_bridged_search_ref
from repro.kernels.mixed_scan.ref import mixed_scan_ref
from repro.launch.roofline import PEAK_FLOPS
from benchmarks.common import Scale, emit, save_json, time_per_call_us


def _bytes_f32(*shapes) -> int:
    return sum(4 * int(np.prod(s)) for s in shapes)


def bench_fused_query_path(
    adapter: DriftAdapter, corpus: jax.Array, batch: int = 256, k: int = 10
) -> dict:
    """Fused one-launch bridged search vs the separate adapter→scan path.

    Same kernels, same math — the only difference is the launch count and
    the HBM round-trip of transformed queries. Parity is asserted exact
    (atol 1e-5 scores, identical ids) against the jnp reference before any
    timing is reported.

    Timing methodology (CPU interpret mode is noisy, ±15% per call): the two
    paths alternate call-for-call and the reported speedup is the MEDIAN of
    per-pair ratios — robust to machine drift (alternation) and stall
    outliers (median). The corpus streams as one block per query tile
    (block_rows = N): interpret mode re-copies constant weight blocks on
    every grid step, which real TPU pipelining does not — matching the
    block count keeps the comparison about the launch + HBM round-trip,
    not that interpreter artifact.
    """
    import statistics
    import time

    n, d_old = corpus.shape
    q = jax.random.normal(jax.random.PRNGKey(3), (batch, adapter.d_new))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
    block_rows = n
    fused_kind, fused = adapter.as_fused_params()

    def unfused(qx):
        q_mapped = adapter_apply_fused(adapter.kind, adapter.params, qx)
        return topk_scan(corpus, q_mapped, k=k, block_rows=block_rows)

    def fused_path(qx):
        return fused_bridged_search(
            fused_kind, fused, qx, corpus, k=k, block_rows=block_rows
        )

    # -- parity gate (the two paths must be THE SAME search) ---------------
    ref_s, ref_i = fused_bridged_search_ref(
        adapter.kind, adapter.params, q, corpus, k=k
    )
    for name, fn in (("unfused", unfused), ("fused", fused_path)):
        s, i = fn(q)
        np.testing.assert_allclose(
            np.asarray(s), np.asarray(ref_s), atol=1e-5,
            err_msg=f"{name} path scores diverge from reference",
        )
        np.testing.assert_array_equal(
            np.asarray(i), np.asarray(ref_i),
            err_msg=f"{name} path ids diverge from reference",
        )

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    samples = {"unfused": [], "fused": []}
    ratios = []
    deltas = []
    for _ in range(60):
        tu = _once(unfused)
        tf = _once(fused_path)
        samples["unfused"].append(tu)
        samples["fused"].append(tf)
        ratios.append(tu / tf)
        deltas.append(tu - tf)
    us_unfused = statistics.median(samples["unfused"])
    us_fused = statistics.median(samples["fused"])
    # paired statistics are the headline: each ratio/delta compares two
    # adjacent calls, immune to the load drift that skews the raw medians
    speedup = statistics.median(ratios)
    delta_us = statistics.median(deltas)

    # -- HBM traffic model (exact f32 byte counts per batch) ---------------
    # Fused reads the pre-folded weights (folded ONCE at install time, not
    # per batch). Unfused reads the raw adapter pytree — and for LA the
    # adapter launch materializes UVᵀ per call (adapter_apply_fused folds
    # inside jit), paying the (d_old, d_new) write + kernel read every batch.
    w_fused = sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(fused)
    )
    w_raw = adapter.param_bytes
    w_unfused = w_raw
    if adapter.kind == "la":
        m_bytes = int(fused["m"].size) * 4
        w_unfused += 2 * m_bytes                  # write UVᵀ + read it back
    out_bytes = _bytes_f32((batch, k), (batch, k))
    roundtrip = 2 * _bytes_f32((batch, d_old))    # write q' + read q' back
    bytes_unfused = (
        _bytes_f32((batch, adapter.d_new), (n, d_old))
        + w_unfused + out_bytes + roundtrip
    )
    bytes_fused = (
        _bytes_f32((batch, adapter.d_new), (n, d_old)) + w_fused + out_bytes
    )
    return {
        "batch": batch,
        "k": k,
        "corpus_rows": n,
        "d": d_old,
        "kernel_launches_unfused": 2,
        "kernel_launches_fused": 1,
        "us_per_batch_unfused": round(us_unfused, 1),
        "us_per_batch_fused": round(us_fused, 1),
        "speedup": round(speedup, 3),
        "paired_delta_us": round(delta_us, 1),
        "hbm_bytes_unfused": bytes_unfused,
        "hbm_bytes_fused": bytes_fused,
        "hbm_bytes_saved_per_batch": bytes_unfused - bytes_fused,
        "parity": "exact (atol 1e-5 scores, ids equal)",
    }


TPU_CAVEAT = (
    "latency numbers are CPU interpret-mode; re-measure on real TPU where "
    "the HBM round-trip and launch overhead dominate and the interpreter's "
    "per-grid-step copies disappear"
)


def bench_mixed_query_path(
    adapter: DriftAdapter,
    corpus: jax.Array,
    batch: int = 256,
    k: int = 10,
    migrated_frac: float = 0.5,
) -> dict:
    """Mixed-state query: one bitmap-masked launch vs the retired two-scan
    merge (PR 3's production path: a bridged scan and a native scan, each
    over-fetching 2k candidates, masked against the migration bitmap and
    merged on host).

    Timing is gated on EXACT score/id parity between the one-pass kernel
    and the jnp two-scan reference (each side masked to its own rows BEFORE
    its top-k — `kernels/mixed_scan/ref.py`); the legacy over-fetch merge
    is additionally scored against that reference, since its 2k window can
    lose candidates (the tail-risk the one-pass kernel removes). Same
    interleaved median-of-pair-ratios methodology as the other sections.
    """
    import statistics
    import time

    n, d = corpus.shape
    rng = np.random.default_rng(11)
    migrated = np.zeros(n, bool)
    migrated[rng.permutation(n)[: int(round(migrated_frac * n))]] = True
    mig = jnp.asarray(migrated)
    q = jax.random.normal(jax.random.PRNGKey(3), (batch, adapter.d_new))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
    block_rows = n
    fused_kind, fused = adapter.as_fused_params()
    neg = float(jnp.finfo(jnp.float32).min)
    kk = min(2 * k, n)

    def two_scan(qx):
        # the retired mixed-state production path, verbatim: over-fetch 2k
        # per side, mask by ownership, merge on host
        s_b, i_b = fused_bridged_search(
            fused_kind, fused, qx, corpus, k=kk, block_rows=block_rows
        )
        s_n, i_n = topk_scan(corpus, qx, k=kk, block_rows=block_rows)
        own_b = (i_b >= 0) & ~mig[jnp.clip(i_b, 0)]
        own_n = (i_n >= 0) & mig[jnp.clip(i_n, 0)]
        s = jnp.concatenate(
            [jnp.where(own_b, s_b, neg), jnp.where(own_n, s_n, neg)], axis=1
        )
        i = jnp.concatenate([i_b, i_n], axis=1)
        top_s, pos = jax.lax.top_k(s, k)
        top_i = jnp.take_along_axis(i, pos, axis=1)
        return top_s, jnp.where(top_s > neg, top_i, -1)

    def one_pass(qx):
        return mixed_bridged_search(
            fused_kind, fused, qx, corpus, mig, k=k, block_rows=block_rows
        )

    # -- parity gate (one-pass kernel vs the exact two-scan reference) -----
    ref_s, ref_i = mixed_scan_ref(
        adapter.kind, adapter.params, q, corpus, mig, k=k
    )
    s, i = one_pass(q)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(ref_s), atol=1e-5,
        err_msg="one-pass mixed scan scores diverge from reference",
    )
    np.testing.assert_array_equal(
        np.asarray(i), np.asarray(ref_i),
        err_msg="one-pass mixed scan ids diverge from reference",
    )
    # the legacy merge is NOT gated — its over-fetch window is approximate;
    # report how often it disagrees with the exact result instead
    _, legacy_i = two_scan(q)
    overfetch_mismatches = int(
        (np.asarray(legacy_i) != np.asarray(ref_i)).sum()
    )

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    samples = {"two_scan": [], "one_pass": []}
    ratios = []
    for _ in range(20):
        tu = _once(two_scan)
        tf = _once(one_pass)
        samples["two_scan"].append(tu)
        samples["one_pass"].append(tf)
        ratios.append(tu / tf)

    # -- HBM traffic model (exact f32 byte counts per batch) ---------------
    # The two-scan path reads the corpus AND the queries twice (one scan
    # each side), writes/reads back 2×(B, 2k) candidate lists for the host
    # merge, and reads the (N,) bitmap once for the ownership masks. The
    # one-pass path reads corpus + queries + bitmap once and writes only
    # the final (B, k).
    w_fused = sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(fused)
    )
    bitmap_bytes = 4 * n
    out_bytes = _bytes_f32((batch, k), (batch, k))
    cand_bytes = 2 * _bytes_f32((batch, kk), (batch, kk))   # write + read
    bytes_two_scan = (
        2 * _bytes_f32((batch, d), (n, d))
        + w_fused + 2 * cand_bytes + bitmap_bytes + out_bytes
    )
    bytes_one_pass = (
        _bytes_f32((batch, d), (n, d)) + w_fused + bitmap_bytes + out_bytes
    )
    return {
        "batch": batch,
        "k": k,
        "corpus_rows": n,
        "d": d,
        "migrated_frac": migrated_frac,
        "kernel_launches_two_scan": 2,
        "kernel_launches_one_pass": 1,
        "us_per_batch_two_scan": round(
            statistics.median(samples["two_scan"]), 1
        ),
        "us_per_batch_one_pass": round(
            statistics.median(samples["one_pass"]), 1
        ),
        "speedup": round(statistics.median(ratios), 3),
        "hbm_bytes_two_scan": bytes_two_scan,
        "hbm_bytes_one_pass": bytes_one_pass,
        "hbm_bytes_saved_per_batch": bytes_two_scan - bytes_one_pass,
        "overfetch_id_mismatches": overfetch_mismatches,
        "parity": "exact vs two-scan reference (atol 1e-5 scores, ids equal)",
        "caveat": TPU_CAVEAT,
    }


def run_mixed(adapter: DriftAdapter | None = None) -> dict:
    """Standalone mixed-state fused-vs-two-scan section → BENCH_mixed.json
    (the CI bench artifact)."""
    d = 768
    if adapter is None:
        key = jax.random.PRNGKey(0)
        b = jax.random.normal(key, (8_000, d))
        b = b / jnp.linalg.norm(b, axis=1, keepdims=True)
        r = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
        adapter = DriftAdapter.fit(
            b, b @ r.T, kind="op",
            config=FitConfig(kind="op", use_dsm=False),
        )
        corpus = (b @ r.T)[:4096]
    else:
        key = jax.random.PRNGKey(0)
        corpus = jax.random.normal(key, (4096, adapter.d_old))
        corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    out = bench_mixed_query_path(adapter, corpus)
    emit("a1.mixed_one_pass.query_path_us", out["us_per_batch_one_pass"],
         out["hbm_bytes_one_pass"])
    emit("a1.mixed_two_scan.query_path_us", out["us_per_batch_two_scan"],
         out["hbm_bytes_two_scan"])
    emit("a1.mixed_one_pass_vs_two_scan.speedup", 0.0, out["speedup"])
    print(f"# caveat: {TPU_CAVEAT}", flush=True)
    save_json("BENCH_mixed", out)
    return out


def bench_engine_packed_dual(
    adapter: DriftAdapter,
    corpus: jax.Array,
    batch: int = 256,
    k: int = 10,
    migrated_frac: float = 0.5,
) -> dict:
    """Packed dual-query mixed scan vs the two-matmul variant (the ROADMAP
    single-matmul open item, now an engine plan knob).

    Both run the SAME engine kernel family — the only difference is the
    query stage: packed stacks [q; g(q)] into one (2·B_tile, d) VMEM
    scratch so each corpus block pays ONE MXU matmul with the bitmap
    selecting post-matmul; unpacked pays two matmuls per block. The gate is
    BIT-exact (scores and ids) between the variants, plus 1e-5 parity
    against the exact two-scan reference. Same interleaved
    median-of-pair-ratios methodology as the other sections. Interpret-mode
    timing mostly reflects the fold, not the MXU — the TPU caveat applies
    doubly here (the packed win is an MXU-pass count, invisible on CPU).
    """
    import statistics
    import time

    n, d = corpus.shape
    rng = np.random.default_rng(13)
    migrated = np.zeros(n, bool)
    migrated[rng.permutation(n)[: int(round(migrated_frac * n))]] = True
    mig = jnp.asarray(migrated)
    q = jax.random.normal(jax.random.PRNGKey(5), (batch, adapter.d_new))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
    block_rows = n
    fused_kind, fused = adapter.as_fused_params()

    def packed(qx):
        return mixed_bridged_search(
            fused_kind, fused, qx, corpus, mig, k=k, block_rows=block_rows,
            packed=True,
        )

    def unpacked(qx):
        return mixed_bridged_search(
            fused_kind, fused, qx, corpus, mig, k=k, block_rows=block_rows,
            packed=False,
        )

    # -- parity gate: BIT-exact between variants, 1e-5 vs the reference ----
    s_p, i_p = packed(q)
    s_u, i_u = unpacked(q)
    np.testing.assert_array_equal(
        np.asarray(s_p), np.asarray(s_u),
        err_msg="packed dual-query scores diverge from the two-matmul scan",
    )
    np.testing.assert_array_equal(
        np.asarray(i_p), np.asarray(i_u),
        err_msg="packed dual-query ids diverge from the two-matmul scan",
    )
    ref_s, ref_i = mixed_scan_ref(
        adapter.kind, adapter.params, q, corpus, mig, k=k
    )
    np.testing.assert_allclose(
        np.asarray(s_p), np.asarray(ref_s), atol=1e-5,
        err_msg="packed dual-query scores diverge from the two-scan ref",
    )
    np.testing.assert_array_equal(
        np.asarray(i_p), np.asarray(ref_i),
        err_msg="packed dual-query ids diverge from the two-scan ref",
    )

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    samples = {"packed": [], "unpacked": []}
    ratios = []
    for _ in range(20):
        tu = _once(unpacked)
        tp = _once(packed)
        samples["unpacked"].append(tu)
        samples["packed"].append(tp)
        ratios.append(tu / tp)

    blocks = -(-n // block_rows)
    return {
        "batch": batch,
        "k": k,
        "corpus_rows": n,
        "d": d,
        "migrated_frac": migrated_frac,
        "kernel_launches_each": 1,
        "matmuls_per_block_packed": 1,
        "matmuls_per_block_unpacked": 2,
        "mxu_passes_saved_per_batch": blocks * -(-batch // 128),
        "us_per_batch_packed": round(statistics.median(samples["packed"]), 1),
        "us_per_batch_unpacked": round(
            statistics.median(samples["unpacked"]), 1
        ),
        "speedup": round(statistics.median(ratios), 3),
        "parity": "bit-exact packed vs unpacked; atol 1e-5 vs two-scan ref",
        "caveat": TPU_CAVEAT + (
            "; the packed win is an MXU-pass count, invisible to the CPU "
            "interpreter"
        ),
    }


def run_engine(adapter: DriftAdapter | None = None) -> dict:
    """Standalone packed-vs-two-matmul engine section → BENCH_engine.json
    (the CI bench artifact)."""
    d = 768
    if adapter is None:
        key = jax.random.PRNGKey(0)
        b = jax.random.normal(key, (8_000, d))
        b = b / jnp.linalg.norm(b, axis=1, keepdims=True)
        r = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
        adapter = DriftAdapter.fit(
            b, b @ r.T, kind="op",
            config=FitConfig(kind="op", use_dsm=False),
        )
        corpus = (b @ r.T)[:4096]
    else:
        key = jax.random.PRNGKey(0)
        corpus = jax.random.normal(key, (4096, adapter.d_old))
        corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    out = bench_engine_packed_dual(adapter, corpus)
    emit("a1.engine_packed.query_path_us", out["us_per_batch_packed"],
         out["mxu_passes_saved_per_batch"])
    emit("a1.engine_unpacked.query_path_us", out["us_per_batch_unpacked"], 0)
    emit("a1.engine_packed_vs_unpacked.speedup", 0.0, out["speedup"])
    print(f"# caveat: {out['caveat']}", flush=True)
    save_json("BENCH_engine", out)
    return out


def bench_ivf_fused_path(
    adapter: DriftAdapter,
    corpus: jax.Array,
    batch: int = 32,
    k: int = 10,
    nprobe: int = 4,
    n_cells: int = 64,
) -> dict:
    """IVF bridged query: fused two-launch path vs the gather+einsum path.

    The unfused path applies the adapter, probes, then materializes the
    probed cells as a (B, nprobe, cap, d) tensor in HBM before the einsum
    (write + read back = 2 extra passes over B·nprobe·cap·d floats). The
    fused path is two kernel launches — adapter-folded centroid probe
    (kernels/fused_search) and streaming gather-rescore
    (kernels/ivf_rescore) — that never build the gathered tensor. Timing is
    gated on EXACT score/id parity between the two paths, same interleaved
    median-of-pair-ratios methodology as bench_fused_query_path.
    """
    import statistics
    import time

    n, d = corpus.shape
    index = build_ivf(jax.random.PRNGKey(7), corpus, n_cells=n_cells)
    fused_index = dataclasses.replace(index, backend="fused")
    cap = index.capacity
    q = jax.random.normal(jax.random.PRNGKey(8), (batch, adapter.d_new))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)

    def unfused(qx):
        return ivf_search(index, adapter.apply(qx), k=k, nprobe=nprobe)

    def fused_path(qx):
        return fused_index.search_bridged(adapter, qx, k=k, nprobe=nprobe)

    # -- parity gate (the two paths must be THE SAME search) ---------------
    ref_s, ref_i = unfused(q)
    s, i = fused_path(q)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(ref_s), atol=1e-5,
        err_msg="fused IVF path scores diverge from the jnp gather path",
    )
    np.testing.assert_array_equal(
        np.asarray(i), np.asarray(ref_i),
        err_msg="fused IVF path ids diverge from the jnp gather path",
    )

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    samples = {"unfused": [], "fused": []}
    ratios = []
    for _ in range(20):
        tu = _once(unfused)
        tf = _once(fused_path)
        samples["unfused"].append(tu)
        samples["fused"].append(tf)
        ratios.append(tu / tf)

    # -- HBM traffic model (exact f32 byte counts per batch) ---------------
    # Both paths read queries + centroid table + probed cells once and
    # write (B, k) results; the unfused path ADDITIONALLY writes the
    # gathered (B, nprobe, cap, d) candidate tensor and reads it back for
    # the einsum, plus round-trips the adapter-transformed queries.
    probe_bytes = _bytes_f32((batch, adapter.d_new), (n_cells, d))
    gather_bytes = _bytes_f32((batch, nprobe, cap, d))
    out_bytes = _bytes_f32((batch, k), (batch, k))
    common = probe_bytes + gather_bytes + out_bytes
    bytes_unfused = common + 2 * gather_bytes + 2 * _bytes_f32((batch, d))
    bytes_fused = common + _bytes_f32((batch, d))   # q' emitted once (probe
    #                                                 launch → rescore read)
    return {
        "batch": batch,
        "k": k,
        "nprobe": nprobe,
        "n_cells": n_cells,
        "cell_capacity": cap,
        "corpus_rows": n,
        "d": d,
        "kernel_launches_fused": 2,
        "us_per_batch_unfused": round(statistics.median(samples["unfused"]), 1),
        "us_per_batch_fused": round(statistics.median(samples["fused"]), 1),
        "speedup": round(statistics.median(ratios), 3),
        "hbm_bytes_unfused": bytes_unfused,
        "hbm_bytes_fused": bytes_fused,
        "hbm_bytes_saved_per_batch": bytes_unfused - bytes_fused,
        "gather_bytes_not_materialized": 2 * gather_bytes,
        "parity": "exact (atol 1e-5 scores, ids equal)",
        "caveat": TPU_CAVEAT,
    }


def run_ivf(adapter: DriftAdapter | None = None) -> dict:
    """Standalone IVF fused-vs-unfused section → BENCH_ivf.json (the CI
    bench artifact)."""
    d = 768
    if adapter is None:
        key = jax.random.PRNGKey(0)
        b = jax.random.normal(key, (8_000, d))
        b = b / jnp.linalg.norm(b, axis=1, keepdims=True)
        r = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
        adapter = DriftAdapter.fit(
            b, b @ r.T, kind="op",
            config=FitConfig(kind="op", use_dsm=False),
        )
        corpus = (b @ r.T)[:4096]
    else:
        key = jax.random.PRNGKey(0)
        corpus = jax.random.normal(key, (4096, adapter.d_old))
        corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    out = bench_ivf_fused_path(adapter, corpus)
    emit("a1.ivf_fused.query_path_us", out["us_per_batch_fused"],
         out["hbm_bytes_fused"])
    emit("a1.ivf_unfused.query_path_us", out["us_per_batch_unfused"],
         out["hbm_bytes_unfused"])
    emit("a1.ivf_fused_vs_unfused.speedup", 0.0, out["speedup"])
    print(f"# caveat: {TPU_CAVEAT}", flush=True)
    save_json("BENCH_ivf", out)
    return out


def bench_quantized_path(
    k: int = 10,
    flat_n: int = 4096,
    ivf_n: int = 2048,
    d: int = 256,
    batch: int = 64,
    nprobe: int = 8,
    n_cells: int = 32,
) -> dict:
    """Int8 first-pass scan + exact fp32 shortlist rescore vs the fp32
    serving path, flat AND IVF, through ScanPlan → BENCH_quant.json.

    The capacity win is the BYTES-SCANNED accounting (exact, counted from
    the operand shapes the first-pass launch streams): int8 codes + one f32
    scale per row vs f32 rows — ~4× at any realistic d. Recall parity
    (≥ 0.99 R@10, gated by check_bench) is measured against the exhaustive
    fp32 oracle with the default ``shortlist_k = 4·k``. Latency is timed
    with the interleaved median-of-pair-ratios methodology, but on CPU the
    int8 path pays two interpreted launches vs one — the speedup floor is
    interpret-advisory in the baseline, the TPU projection is where the
    4× fewer first-pass bytes cash out.
    """
    import statistics
    import time

    from repro.ann import FlatIndex, recall_at_k
    from repro.kernels.engine import compile_plan, execute_plan

    key = jax.random.PRNGKey(0)
    corpus = jax.random.normal(key, (flat_n, d))
    corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    q = jax.random.normal(jax.random.PRNGKey(8), (batch, d))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
    from repro.ann import flat_search_jnp as _oracle

    _, gt = _oracle(corpus, q, k=k)

    out: dict = {"k": k, "batch": batch, "d": d}

    # -- flat: fp32 one-launch fused scan vs int8 quant-scan + rescore -----
    flat = FlatIndex(corpus=corpus, backend="fused").quantize()
    plan32 = compile_plan(flat)
    plan8 = compile_plan(flat, precision="int8")
    shortlist = plan8.shortlist(k, flat_n)

    def flat_fp32(qx):
        return execute_plan(plan32, qx, index=flat, k=k)

    def flat_int8(qx):
        return execute_plan(plan8, qx, index=flat, k=k)

    r32 = float(recall_at_k(flat_fp32(q)[1], gt))
    r8 = float(recall_at_k(flat_int8(q)[1], gt))

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    for fn in (flat_fp32, flat_int8):
        _once(fn)                       # compile outside the timed loop
    samples: dict = {"fp32": [], "int8": []}
    ratios = []
    for _ in range(10):
        t32 = _once(flat_fp32)
        t8 = _once(flat_int8)
        samples["fp32"].append(t32)
        samples["int8"].append(t8)
        ratios.append(t32 / t8)

    # first-pass bytes: what the scan launch streams from HBM per batch —
    # fp32 rows vs int8 codes + one f32 scale per row
    fp32_bytes = _bytes_f32((flat_n, d))
    int8_bytes = flat_n * d + _bytes_f32((flat_n,))
    # rescore DMA: one (cap, d) f32 tile per (query, shortlist slot)
    cap = flat.rcells.shape[1]
    rescore_bytes = _bytes_f32((batch, shortlist, cap, d))
    out["flat"] = {
        "n": flat_n,
        "shortlist_k": shortlist,
        "kernels": list(plan8.kernels()),
        "launches": plan8.launch_count,
        "recall_fp32": round(r32, 4),
        "recall_int8": round(r8, 4),
        "recall_parity": round(r8 / r32, 4) if r32 else 0.0,
        "first_pass_bytes_fp32": fp32_bytes,
        "first_pass_bytes_int8": int8_bytes,
        "first_pass_bytes_ratio": round(fp32_bytes / int8_bytes, 3),
        "rescore_bytes_int8": rescore_bytes,
        "us_per_batch_fp32": round(statistics.median(samples["fp32"]), 1),
        "us_per_batch_int8": round(statistics.median(samples["int8"]), 1),
        "speedup": round(statistics.median(ratios), 3),
    }

    # -- IVF: fp32 probe+rescore vs probe + int8 scan + exact rescore ------
    ivf = build_ivf(jax.random.PRNGKey(7), corpus[:ivf_n], n_cells=n_cells)
    ivf = dataclasses.replace(ivf, backend="fused").quantize()
    _, gt_ivf = _oracle(corpus[:ivf_n], q, k=k)
    iplan32 = compile_plan(ivf)
    iplan8 = compile_plan(ivf, precision="int8")
    ishort = iplan8.shortlist(k, ivf_n)

    def ivf_fp32(qx):
        return execute_plan(iplan32, qx, index=ivf, k=k, nprobe=nprobe)

    def ivf_int8(qx):
        return execute_plan(iplan8, qx, index=ivf, k=k, nprobe=nprobe)

    ir32 = float(recall_at_k(ivf_fp32(q)[1], gt_ivf))
    ir8 = float(recall_at_k(ivf_int8(q)[1], gt_ivf))
    for fn in (ivf_fp32, ivf_int8):
        _once(fn)
    isamples: dict = {"fp32": [], "int8": []}
    iratios = []
    for _ in range(10):
        t32 = _once(ivf_fp32)
        t8 = _once(ivf_int8)
        isamples["fp32"].append(t32)
        isamples["int8"].append(t8)
        iratios.append(t32 / t8)

    icap = ivf.capacity
    # first pass streams nprobe (cap, d) cell tiles per query
    ifp32_bytes = _bytes_f32((batch, nprobe, icap, d))
    iint8_bytes = batch * nprobe * icap * d + _bytes_f32(
        (batch, nprobe, icap)
    )
    out["ivf"] = {
        "n": ivf_n,
        "n_cells": n_cells,
        "cell_capacity": icap,
        "nprobe": nprobe,
        "shortlist_k": ishort,
        "kernels": list(iplan8.kernels()),
        "launches": iplan8.launch_count,
        "recall_fp32": round(ir32, 4),
        "recall_int8": round(ir8, 4),
        "recall_parity": round(ir8 / ir32, 4) if ir32 else 0.0,
        "first_pass_bytes_fp32": ifp32_bytes,
        "first_pass_bytes_int8": iint8_bytes,
        "first_pass_bytes_ratio": round(ifp32_bytes / iint8_bytes, 3),
        "us_per_batch_fp32": round(statistics.median(isamples["fp32"]), 1),
        "us_per_batch_int8": round(statistics.median(isamples["int8"]), 1),
        "speedup": round(statistics.median(iratios), 3),
    }
    out["caveat"] = TPU_CAVEAT
    return out


def run_quant() -> dict:
    """Standalone quantized-path section → BENCH_quant.json (the CI bench
    artifact gating recall parity + first-pass bytes)."""
    out = bench_quantized_path()
    for side in ("flat", "ivf"):
        emit(f"a1.quant_{side}.recall_parity", 0.0,
             out[side]["recall_parity"])
        emit(f"a1.quant_{side}.first_pass_bytes_ratio", 0.0,
             out[side]["first_pass_bytes_ratio"])
        emit(f"a1.quant_{side}.us_per_batch_int8",
             out[side]["us_per_batch_int8"], out[side]["speedup"])
    print(f"# caveat: {TPU_CAVEAT}", flush=True)
    save_json("BENCH_quant", out)
    return out


def bench_binary_path(
    k: int = 10,
    flat_n: int = 4096,
    ivf_n: int = 2048,
    d: int = 256,
    batch: int = 64,
    nprobe: int = 8,
    n_cells: int = 32,
    group: int = 16,
    noise: float = 0.5,
) -> dict:
    """Bit-packed sign-bit first-pass scan + exact fp32 shortlist rescore
    vs the fp32 serving path, flat AND IVF, through ScanPlan →
    BENCH_binary.json.

    The capacity win is BYTES-SCANNED: one uint32 word per 32 dims vs f32
    rows — 32× at d=256 (8× smaller than the int8 codes+scales plane).
    Recall parity is measured on a near-duplicate grouped corpus: every
    ``group`` rows share a unit centroid plus a norm-``noise`` perturbation,
    and queries perturb a centroid the same way. That is the regime 1-bit
    signatures are built for (dedup/retrieval over drifting re-embeddings
    of the same items, the paper's setting); on an isotropic gaussian
    corpus all dots are ~0 and sign agreement carries no signal, so no
    shortlist multiple recovers fp32's arbitrary ordering. Parity
    (≥ 0.99 R@10, hard-gated by check_bench) uses the default
    ``shortlist_k = 4·k``; latency keeps the interleaved
    median-of-pair-ratios methodology with the speedup interpret-advisory
    (the TPU projection is where 32× fewer first-pass bytes cash out).
    """
    import statistics
    import time

    from repro.ann import FlatIndex, recall_at_k
    from repro.kernels.engine import compile_plan, execute_plan
    from repro.kernels.engine.core import bin_words

    def _unit(x):
        return x / jnp.linalg.norm(x, axis=-1, keepdims=True)

    n_groups = flat_n // group
    cent = _unit(jax.random.normal(jax.random.PRNGKey(0), (n_groups, d)))
    corpus = _unit(
        jnp.repeat(cent, group, axis=0)
        + noise * _unit(jax.random.normal(jax.random.PRNGKey(1),
                                          (flat_n, d)))
    )
    # draw query groups from the ivf_n prefix so every query's group
    # exists in BOTH corpora (the IVF arm indexes corpus[:ivf_n])
    gq = jax.random.choice(jax.random.PRNGKey(2), ivf_n // group, (batch,),
                           replace=False)
    q = _unit(cent[gq] + noise * _unit(
        jax.random.normal(jax.random.PRNGKey(3), (batch, d))))
    from repro.ann import flat_search_jnp as _oracle

    _, gt = _oracle(corpus, q, k=k)

    out: dict = {"k": k, "batch": batch, "d": d, "group": group,
                 "noise": noise}

    # -- flat: fp32 one-launch fused scan vs binary scan + rescore ---------
    flat = FlatIndex(corpus=corpus, backend="fused").binarize()
    plan32 = compile_plan(flat)
    planb = compile_plan(flat, precision="binary")
    shortlist = planb.shortlist(k, flat_n)

    def flat_fp32(qx):
        return execute_plan(plan32, qx, index=flat, k=k)

    def flat_bin(qx):
        return execute_plan(planb, qx, index=flat, k=k)

    r32 = float(recall_at_k(flat_fp32(q)[1], gt))
    rb = float(recall_at_k(flat_bin(q)[1], gt))

    def _once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(q))
        return (time.perf_counter() - t0) * 1e6

    for fn in (flat_fp32, flat_bin):
        _once(fn)                       # compile outside the timed loop
    samples: dict = {"fp32": [], "binary": []}
    ratios = []
    for _ in range(10):
        t32 = _once(flat_fp32)
        tb = _once(flat_bin)
        samples["fp32"].append(t32)
        samples["binary"].append(tb)
        ratios.append(t32 / tb)

    # first-pass bytes: fp32 rows vs packed sign words (vs int8 for the
    # intermediate-tier ratio — codes + one f32 scale per row)
    w = bin_words(d)
    fp32_bytes = _bytes_f32((flat_n, d))
    int8_bytes = flat_n * d + _bytes_f32((flat_n,))
    bin_bytes = 4 * flat_n * w
    cap = flat.rcells.shape[1]
    rescore_bytes = _bytes_f32((batch, shortlist, cap, d))
    out["flat"] = {
        "n": flat_n,
        "shortlist_k": shortlist,
        "kernels": list(planb.kernels()),
        "launches": planb.launch_count,
        "recall_fp32": round(r32, 4),
        "recall_binary": round(rb, 4),
        "recall_parity": round(rb / r32, 4) if r32 else 0.0,
        "first_pass_bytes_fp32": fp32_bytes,
        "first_pass_bytes_int8": int8_bytes,
        "first_pass_bytes_binary": bin_bytes,
        "first_pass_bytes_ratio": round(fp32_bytes / bin_bytes, 3),
        "first_pass_bytes_ratio_vs_int8": round(int8_bytes / bin_bytes, 3),
        "rescore_bytes_binary": rescore_bytes,
        "us_per_batch_fp32": round(statistics.median(samples["fp32"]), 1),
        "us_per_batch_binary": round(
            statistics.median(samples["binary"]), 1),
        "speedup": round(statistics.median(ratios), 3),
    }

    # -- IVF: fp32 probe+scan vs probe + binary scan + exact rescore -------
    ivf = build_ivf(jax.random.PRNGKey(7), corpus[:ivf_n], n_cells=n_cells)
    ivf = dataclasses.replace(ivf, backend="fused").binarize()
    _, gt_ivf = _oracle(corpus[:ivf_n], q, k=k)
    iplan32 = compile_plan(ivf)
    iplanb = compile_plan(ivf, precision="binary")
    ishort = iplanb.shortlist(k, ivf_n)

    def ivf_fp32(qx):
        return execute_plan(iplan32, qx, index=ivf, k=k, nprobe=nprobe)

    def ivf_bin(qx):
        return execute_plan(iplanb, qx, index=ivf, k=k, nprobe=nprobe)

    ir32 = float(recall_at_k(ivf_fp32(q)[1], gt_ivf))
    irb = float(recall_at_k(ivf_bin(q)[1], gt_ivf))
    for fn in (ivf_fp32, ivf_bin):
        _once(fn)
    isamples: dict = {"fp32": [], "binary": []}
    iratios = []
    for _ in range(10):
        t32 = _once(ivf_fp32)
        tb = _once(ivf_bin)
        isamples["fp32"].append(t32)
        isamples["binary"].append(tb)
        iratios.append(t32 / tb)

    icap = ivf.capacity
    # first pass streams nprobe cell tiles per query: (cap, d) f32 vs
    # (cap, w) packed uint32 (vs int8 codes + per-slot scales)
    ifp32_bytes = _bytes_f32((batch, nprobe, icap, d))
    iint8_bytes = batch * nprobe * icap * d + _bytes_f32(
        (batch, nprobe, icap)
    )
    ibin_bytes = 4 * batch * nprobe * icap * w
    out["ivf"] = {
        "n": ivf_n,
        "n_cells": n_cells,
        "cell_capacity": icap,
        "nprobe": nprobe,
        "shortlist_k": ishort,
        "kernels": list(iplanb.kernels()),
        "launches": iplanb.launch_count,
        "recall_fp32": round(ir32, 4),
        "recall_binary": round(irb, 4),
        "recall_parity": round(irb / ir32, 4) if ir32 else 0.0,
        "first_pass_bytes_fp32": ifp32_bytes,
        "first_pass_bytes_int8": iint8_bytes,
        "first_pass_bytes_binary": ibin_bytes,
        "first_pass_bytes_ratio": round(ifp32_bytes / ibin_bytes, 3),
        "first_pass_bytes_ratio_vs_int8": round(
            iint8_bytes / ibin_bytes, 3),
        "us_per_batch_fp32": round(statistics.median(isamples["fp32"]), 1),
        "us_per_batch_binary": round(
            statistics.median(isamples["binary"]), 1),
        "speedup": round(statistics.median(iratios), 3),
    }
    out["caveat"] = TPU_CAVEAT
    return out


def run_binary() -> dict:
    """Standalone binary-path section → BENCH_binary.json (the CI bench
    artifact gating recall parity + packed first-pass bytes)."""
    out = bench_binary_path()
    for side in ("flat", "ivf"):
        emit(f"a1.binary_{side}.recall_parity", 0.0,
             out[side]["recall_parity"])
        emit(f"a1.binary_{side}.first_pass_bytes_ratio", 0.0,
             out[side]["first_pass_bytes_ratio"])
        emit(f"a1.binary_{side}.us_per_batch_binary",
             out[side]["us_per_batch_binary"], out[side]["speedup"])
    print(f"# caveat: {TPU_CAVEAT}", flush=True)
    save_json("BENCH_binary", out)
    return out


def run(scale: Scale) -> dict:
    d = 768
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (20_000, d))
    b = b / jnp.linalg.norm(b, axis=1, keepdims=True)
    r = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
    a = b @ r.T

    out: dict = {"adapters": {}}
    fit_seconds_mlp = None
    adapter_la = None
    for kind, dsm in (("op", False), ("la", True), ("mlp", True)):
        ad = DriftAdapter.fit(
            b, a, kind=kind,
            config=FitConfig(kind=kind, use_dsm=dsm, max_epochs=10),
        )
        apply_jit = jax.jit(lambda q, _ad=ad: _ad.apply(q))
        batch = b[:1024]
        us_cpu = time_per_call_us(apply_jit, batch, per_call_items=1024)
        us_tpu = ad.flops_per_query / PEAK_FLOPS * 1e6
        row = {
            "param_bytes": ad.param_bytes,
            "param_mb": round(ad.param_bytes / 2**20, 3),
            "flops_per_query": ad.flops_per_query,
            "us_per_query_cpu": round(us_cpu, 2),
            "us_per_query_tpu_roofline": round(us_tpu, 5),
            "fit_seconds": round(ad.fit_info.fit_seconds, 2),
        }
        out["adapters"][kind] = row
        if kind == "mlp":
            fit_seconds_mlp = ad.fit_info.fit_seconds
        if kind == "la":
            adapter_la = ad
        emit(f"a1.{kind}.apply_us_cpu", us_cpu, ad.param_bytes)

    # Fused one-pass bridged query path vs separate adapter→scan launches
    # (LA adapter: exercises the UVᵀ precompose the fused path is built on)
    corpus = a[:2048]
    fused = bench_fused_query_path(adapter_la, corpus, batch=256, k=10)
    out["fused_query_path"] = fused
    emit("a1.fused.query_path_us", fused["us_per_batch_fused"],
         fused["hbm_bytes_fused"])
    emit("a1.unfused.query_path_us", fused["us_per_batch_unfused"],
         fused["hbm_bytes_unfused"])
    emit("a1.fused_vs_unfused.paired_delta_us", fused["paired_delta_us"],
         fused["speedup"])

    # IVF bridged path: two fused launches vs adapter + gather + einsum
    out["ivf_query_path"] = run_ivf(adapter_la)

    # Mixed-state path: one bitmap-masked launch vs the two-scan merge
    out["mixed_query_path"] = run_mixed(adapter_la)

    # Engine packed dual-query vs two-matmul mixed scan
    out["engine_packed_dual"] = run_engine(adapter_la)
    out["caveat"] = TPU_CAVEAT

    # Table 5 projection — adapter columns measured, re-embed/build modeled
    embed_rate = 400.0          # items / GPU-second (A100, d=768 encoder)
    hnsw_ms = {1e6: 0.5, 1e8: 5.0, 1e9: 15.0}
    t5 = {}
    for n in (1e6, 1e8, 1e9):
        gpu_hr = n / embed_rate / 3600
        t5[f"{int(n):,}"] = {
            "reembed_gpu_hours_model": round(gpu_hr, 1),
            "adapter_fit_seconds_measured": round(fit_seconds_mlp, 1),
            "adapter_added_us": out["adapters"]["mlp"]["us_per_query_cpu"],
            "query_ms_before": hnsw_ms[n],
            "query_ms_after": round(
                hnsw_ms[n]
                + out["adapters"]["mlp"]["us_per_query_cpu"] / 1000, 4
            ),
        }
        emit(f"t5.scale_{int(n)}.query_ms_after", 0.0,
             t5[f"{int(n):,}"]["query_ms_after"])
    out["t5_projection"] = t5
    print(f"# caveat: {TPU_CAVEAT}", flush=True)
    save_json("memory_latency", out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--ivf-only", action="store_true",
        help="run just the IVF fused-vs-unfused section (the CI bench "
        "artifact: BENCH_ivf.json)",
    )
    ap.add_argument(
        "--mixed-only", action="store_true",
        help="run just the mixed-state one-pass-vs-two-scan section (the "
        "CI bench artifact: BENCH_mixed.json)",
    )
    ap.add_argument(
        "--engine-only", action="store_true",
        help="run just the packed-dual-query vs two-matmul engine section "
        "(the CI bench artifact: BENCH_engine.json)",
    )
    ap.add_argument(
        "--quant-only", action="store_true",
        help="run just the int8-first-pass vs fp32 serving section "
        "(the CI bench artifact: BENCH_quant.json)",
    )
    ap.add_argument(
        "--binary-only", action="store_true",
        help="run just the bit-packed-binary vs fp32 serving section "
        "(the CI bench artifact: BENCH_binary.json)",
    )
    args = ap.parse_args()
    if args.ivf_only:
        run_ivf()
    elif args.mixed_only:
        run_mixed()
    elif args.engine_only:
        run_engine()
    elif args.quant_only:
        run_quant()
    elif args.binary_only:
        run_binary()
    else:
        from benchmarks.common import DEFAULT

        run(DEFAULT)
