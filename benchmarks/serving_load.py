"""Open-loop serving-load benchmark for the front door → BENCH_serving.json.

Closed-loop harnesses (submit a batch, wait, repeat) hide queueing: the
generator slows down with the server, so tail latency under real traffic
never shows. This benchmark is OPEN-LOOP: request arrival times are a
pre-drawn Poisson process, each request's enqueue timestamp is its
SCHEDULED arrival (not the moment the driver got to it), and the offered
rate never adapts — exactly the "p50/p99 under load, not per-batch
best-of-N" measurement the ROADMAP calls for.

The world is the hardest serving state the store has: mid-migration (v2
traffic rides the bitmap-masked mixed scan, v1 control traffic the
inverse-mixed scan) plus a third registered space v3 (mixed-bridged), two
tenants, all through one :class:`FrontDoor`. Three phases:

* **parity** (hard gate): every front-door result must be bit-identical to
  serving that request alone through ``VectorStore.search``, and the mixed
  3-plan stream must drain in exactly 3 coalesced plan executions
  (telemetry-counted).
* **load arms** (goodput hard, latencies interpret-advisory): the Poisson
  generator at ~0.5× and ~3× of the measured drain capacity; p50/p99
  wait/total latency, goodput, and coalescing factor vs offered load.
* **shed** (hard): the overloaded arm with deadlines — deadline-expired
  requests must be explicitly Rejected (≥1 shed, zero silent drops:
  offered == completed + rejected).

    PYTHONPATH=src python -m benchmarks.serving_load --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.ann import FlatIndex
from repro.core import DriftAdapter, FitConfig
from repro.data import CorpusConfig, MILD_TEXT, make_corpus, make_drift, make_queries
from repro.serve import FrontDoor, VectorStore

SPACES = ("v2", "v2", "v1", "v3")      # the traffic mix, cycled per request
TENANTS = ("gold", "free")


def build_world(items: int, dim: int, n_queries: int, adapter: str):
    """Mid-migration VectorStore with three live spaces + per-space queries."""
    ccfg = CorpusConfig(n_items=items, dim=dim,
                        n_clusters=max(64, items // 150), seed=0)
    corpus_old, _ = make_corpus(ccfg)
    base = dataclasses.replace(MILD_TEXT, d_old=dim, d_new=dim)
    drift_v2 = make_drift(base)
    drift_v3 = make_drift(dataclasses.replace(base, rotation_theta=0.3, seed=3))
    corpus_v2 = drift_v2(corpus_old, 0)
    q_raw = make_queries(ccfg, n_queries)[0]
    queries = {
        "v1": np.asarray(q_raw, np.float32),
        "v2": np.asarray(drift_v2(q_raw, 1), np.float32),
        "v3": np.asarray(drift_v3(q_raw, 1), np.float32),
    }

    store = VectorStore(FlatIndex(corpus=corpus_old, backend="fused"),
                        version="v1")
    store.attach_telemetry()
    handle = store.upgrade(
        "v2", corpus_new_provider=lambda ids: corpus_v2[jnp.asarray(ids)],
    )
    n_pairs = min(5_000, items)
    handle.fit(corpus_v2[:n_pairs], corpus_old[:n_pairs],
               config=FitConfig(kind=adapter))
    handle.deploy()
    handle.migrate_batch(int(items * 0.4))        # mixed-state serving

    # third space: register v3 -> v1 so mixed-bridged traffic is live too
    store.registry.add_version("v3", dim)
    corpus_v3 = drift_v3(corpus_old, 0)
    store.registry.register_edge("v3", "v1", DriftAdapter.fit(
        corpus_v3[:n_pairs], corpus_old[:n_pairs],
        config=FitConfig(kind=adapter),
    ))
    return store, queries


def request_stream(queries: dict, n: int):
    """The deterministic mixed stream: (embedding, space, tenant) per rid."""
    out = []
    for i in range(n):
        space = SPACES[i % len(SPACES)]
        q = queries[space][i % queries[space].shape[0]]
        out.append((q, space, TENANTS[i % len(TENANTS)]))
    return out


def run_parity(store, queries, n: int, k: int) -> dict:
    """Hard-gate phase: coalesced == individual, G plans ⇒ G executions."""
    door = FrontDoor(store, max_depth=4 * n)
    stream = request_stream(queries, n)
    requests = [
        door.submit(q, space=space, k=k, tenant=tenant)
        for q, space, tenant in stream
    ]
    plans_before = store.telemetry.plans_executed
    summary = door.drain()
    plan_executions = store.telemetry.plans_executed - plans_before

    matched = 0
    for r in requests:
        ref = store.search(jnp.asarray(r.embedding[None]), k=k, space=r.space)
        if (
            np.array_equal(r.result.ids, np.asarray(ref.ids[0]))
            and np.array_equal(r.result.scores, np.asarray(ref.scores[0]))
        ):
            matched += 1
    paths = sorted({r.result.path for r in requests})
    return {
        "checked": n,
        "matched": matched,
        "rate": matched / n,
        "bit_identical": matched == n,
        "paths": paths,
        "plan_groups": summary["groups"],
        "dispatches": summary["dispatches"],
        "plan_executions": plan_executions,
    }


def run_open_loop(
    store, queries, n: int, rate: float, k: int,
    deadline_s: float | None = None, seed: int = 0,
) -> dict:
    """Drive one open-loop arm at ``rate`` req/s; returns the SLO rollup.

    The driver is single-threaded: each cycle pushes every arrival whose
    scheduled time has passed (stamping ``t_enqueue`` with the SCHEDULED
    time, so backlog the driver itself accrued counts against latency),
    then drains once. Service time never throttles the offered schedule.
    """
    door = FrontDoor(store, max_depth=16 * n)
    stream = request_stream(queries, n)
    arrivals = np.random.default_rng(seed).exponential(1.0 / rate, n).cumsum()
    t0 = time.perf_counter()
    i = 0
    while i < n or door.depth > 0:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            q, space, tenant = stream[i]
            door.submit(
                q, space=space, k=k, tenant=tenant,
                deadline_s=deadline_s, now=t0 + arrivals[i],
            )
            i += 1
        if door.depth:
            door.drain()
        elif i < n:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.01))
    rollup = door.slo_rollup()
    rollup["offered_rate"] = rate
    rollup["duration_s"] = time.perf_counter() - t0
    rollup["coalescing_factor"] = (
        rollup["completed"] / rollup["dispatches"]
        if rollup["dispatches"] else 0.0
    )
    rollup["rejected_deadline"] = rollup["rejected"].get("deadline", 0)
    return rollup


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2k items, dim 64, short arms")
    ap.add_argument("--items", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per load arm")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--adapter", default="op", choices=["op", "la", "mlp"])
    args = ap.parse_args()
    items = args.items or (2_000 if args.smoke else 20_000)
    dim = args.dim or (64 if args.smoke else 256)
    n_req = args.requests or (160 if args.smoke else 600)

    store, queries = build_world(items, dim, max(n_req, 256), args.adapter)

    # phase 1: parity + coalescing invariants (also warms every plan trace)
    parity = run_parity(store, queries, n=min(64, n_req), k=args.k)
    emit("serving_parity", 0.0, parity["rate"])
    print(f"# parity {parity['matched']}/{parity['checked']} "
          f"groups={parity['plan_groups']} "
          f"plan_executions={parity['plan_executions']} "
          f"paths={parity['paths']}")

    # capacity probe: one full-mix drain, all plans already traced
    t0 = time.perf_counter()
    run_parity(store, queries, n=min(64, n_req), k=args.k)
    probe_dt = time.perf_counter() - t0
    capacity = min(64, n_req) / probe_dt      # req/s through a loaded drain

    arms = {}
    for name, mult in (("low", 0.5), ("high", 3.0)):
        rollup = run_open_loop(
            store, queries, n=n_req, rate=capacity * mult, k=args.k,
            seed=11 if name == "low" else 13,
        )
        arms[name] = rollup
        emit(f"serving_load_{name}", rollup["total_p50_ms"] * 1e3,
             rollup["goodput"])
        print(f"# {name}: offered={rollup['offered_rate']:.0f}/s "
              f"p50={rollup['total_p50_ms']:.1f}ms "
              f"p99={rollup['total_p99_ms']:.1f}ms "
              f"goodput={rollup['goodput']:.3f} "
              f"coalescing={rollup['coalescing_factor']:.1f}")

    # shed phase: overload with a deadline each request can miss
    shed = run_open_loop(
        store, queries, n=n_req, rate=capacity * 3.0, k=args.k,
        deadline_s=probe_dt / min(64, n_req), seed=17,
    )
    emit("serving_shed", shed["total_p50_ms"] * 1e3, shed["rejected_deadline"])
    print(f"# shed: rejected_deadline={shed['rejected_deadline']} "
          f"late={shed['late']} goodput={shed['goodput']:.3f} "
          f"conservation_ok={shed['conservation_ok']}")

    save_json("BENCH_serving", {
        "config": {
            "items": items, "dim": dim, "requests": n_req, "k": args.k,
            "adapter": args.adapter, "spaces": list(SPACES),
            "tenants": list(TENANTS),
            "capacity_probe_rps": capacity,
            "platform": jax.default_backend(),
        },
        "caveat": (
            "CPU interpret-mode latencies; re-measure on real TPU"
            if jax.default_backend() == "cpu" else ""
        ),
        "parity": parity,
        "arms": arms,
        "shed": shed,
        "telemetry": store.telemetry.counters(),
    })
    print("wrote BENCH_serving.json")

    # the benchmark's own hard gates (CI re-asserts via check_bench)
    if not parity["bit_identical"]:
        raise SystemExit("serving gate: front-door results not bit-identical")
    if parity["plan_executions"] != parity["plan_groups"]:
        raise SystemExit(
            f"serving gate: {parity['plan_groups']} plan groups took "
            f"{parity['plan_executions']} plan executions"
        )
    if shed["rejected_deadline"] < 1:
        raise SystemExit("serving gate: overloaded deadline arm shed nothing")
    for name, rollup in (("low", arms["low"]), ("high", arms["high"]),
                         ("shed", shed)):
        if not rollup["conservation_ok"]:
            raise SystemExit(f"serving gate: {name} arm dropped requests")


if __name__ == "__main__":
    main()
