"""Table 4 — drastic drift (GloVe 300d → MPNet 768d analogue).

Severe preset: full-rank large rotation + strong nonlinearity + heavy
scaling/noise. Per the paper, DSM is applied to ALL adapter variants here
(variance shifts are pronounced across disparate model families). The
expected reproduction signature: misaligned collapses (~0.2), linear
adapters recover partially, MLP leads — the "diagnostic signal" of §5.3.
"""
from __future__ import annotations


from repro.data.drift import SEVERE_GLOVE
from benchmarks.common import Scale, build_scenario, emit, fit_and_eval, save_json


def run(scale: Scale) -> dict:
    results: dict = {}
    scen = build_scenario(
        "t4_severe", SEVERE_GLOVE, scale, corpus_seed=13, pair_seed=99
    )
    results["misaligned"] = {"r10_arr": scen.misaligned_r10}
    emit("t4.glove_mpnet.misaligned.r10_arr", 0.0,
         round(scen.misaligned_r10, 4))
    for kind in ("op", "la", "mlp"):
        r = fit_and_eval(scen, kind, use_dsm=True)   # DSM for ALL (paper §5.3)
        results[kind] = r
        emit(f"t4.glove_mpnet.{kind}.r10_arr",
             r["fit_seconds"] * 1e6, round(r["r10_arr"], 4))
    save_json("t4_severe", results)
    return results
