"""§5.6 — continuous online adaptation under lazy background re-embedding.

Scenario: 5 % of the corpus is re-encoded with f_new each hour and moved to
a new-space segment. Ground truth is the evolving oracle (all-new space).

Strategies compared over 24 ticks:
  * fixed_t0  — the T=0 adapter maps every query into the legacy space and
    searches the WHOLE mixed index with it: refreshed (new-space) rows are
    increasingly mismatched → ARR decays toward the paper's ~0.83.
  * online    — segment-aware serving + hourly refit: the old segment is
    searched with g(q), the new segment with q directly, top-k merged; the
    adapter refits each tick on the pairs the re-embedder just produced
    (rolling buffer). ARR stays > 0.95 (paper's claim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import flat_search_jnp, recall_at_k
from repro.core import DriftAdapter, FitConfig, OnlineAdapterManager, OnlineConfig
from repro.data.drift import MILD_TEXT
from benchmarks.common import Scale, build_scenario, emit, save_json

TICKS = 24
REFRESH_FRAC = 0.05


def _merge_topk(s1, i1, s2, i2, k):
    s = jnp.concatenate([s1, s2], axis=1)
    i = jnp.concatenate([i1, i2], axis=1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def run(scale: Scale) -> dict:
    n = min(scale.n_items, 100_000)
    scen = build_scenario(
        "online", MILD_TEXT, Scale(n_items=n, n_queries=scale.n_queries,
                                   n_pairs=scale.n_pairs),
        corpus_seed=0, pair_seed=5,
    )
    k = 10
    rng = np.random.default_rng(0)
    order = rng.permutation(n)          # refresh order
    fixed = DriftAdapter.fit(
        scen.pairs_b, scen.pairs_a, kind="mlp",
        config=FitConfig(kind="mlp", use_dsm=True),
    )
    mgr = OnlineAdapterManager(
        d_new=scen.pairs_b.shape[1], d_old=scen.pairs_a.shape[1],
        config=OnlineConfig(kind="mlp", max_epochs_per_refit=10),
    )
    mgr.observe_pairs(np.asarray(scen.pairs_b), np.asarray(scen.pairs_a))
    mgr.tick()

    per_refresh = int(n * REFRESH_FRAC)
    history = {"fixed_t0": [], "online": [], "frac_new": []}
    corpus_mixed = scen.corpus_old
    for t in range(1, TICKS + 1):
        newly = order[(t - 1) * per_refresh : t * per_refresh]
        if len(newly):
            corpus_mixed = corpus_mixed.at[newly].set(scen.corpus_new[newly])
            # background re-embedder emits fresh ⟨f_new, f_old⟩ pairs
            mgr.observe_pairs(
                np.asarray(scen.corpus_new[newly]),
                np.asarray(scen.corpus_old[newly]),
            )
        online_adapter = mgr.tick() or mgr.adapter

        refreshed = order[: t * per_refresh]
        is_new = np.zeros(n, bool)
        is_new[refreshed] = True

        # fixed_t0: one mapped query against the mixed index
        _, ids_fixed = flat_search_jnp(corpus_mixed, fixed.apply(scen.q_new), k=k)
        arr_fixed = float(recall_at_k(ids_fixed, scen.gt))

        # online: segment-aware (old segment via adapter, new directly)
        mask_new = jnp.asarray(is_new)
        old_part = jnp.where(mask_new[:, None], 0.0, scen.corpus_old)
        new_part = jnp.where(mask_new[:, None], scen.corpus_new, 0.0)
        s_o, i_o = flat_search_jnp(old_part, online_adapter.apply(scen.q_new), k=k)
        s_n, i_n = flat_search_jnp(new_part, scen.q_new, k=k)
        _, ids_on = _merge_topk(s_o, i_o, s_n, i_n, k)
        arr_online = float(recall_at_k(ids_on, scen.gt))

        history["fixed_t0"].append(arr_fixed)
        history["online"].append(arr_online)
        history["frac_new"].append(t * REFRESH_FRAC)

    out = {
        "history": history,
        "fixed_final": history["fixed_t0"][-1],
        "online_min": min(history["online"]),
        "refits": mgr.refits,
    }
    emit("online.fixed_t0.final_arr", 0.0, round(out["fixed_final"], 4))
    emit("online.retrained.min_arr", 0.0, round(out["online_min"], 4))
    save_json("online_adaptation", out)
    return out
