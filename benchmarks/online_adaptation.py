"""§5.6 — continuous online adaptation under lazy background re-embedding,
driven through the `VectorStore` upgrade lifecycle.

Scenario: 5 % of the corpus is re-encoded with f_new each hour. Ground truth
is the evolving oracle (all-new space). The mixed-state index, the migration
bitmap, and the serving path all come from the lifecycle API now — nothing
is simulated by hand:

  * fixed_t0  — the T=0 adapter maps every query into the legacy space and
    searches the WHOLE mixed index with it (a bare bridged scan that is
    blind to the migration bitmap): refreshed (new-space) rows are
    increasingly mismatched → ARR decays toward the paper's ~0.83.
  * online    — `store.search` during migration takes the bitmap-masked
    mixed-state path (one fused launch on `backend="fused"`), and an
    `OnlineAdapterManager` DECORATES the upgrade's registry edge
    (`registry=, src=, dst=`): each tick it refits on the pairs the
    re-embedder just produced and atomically replaces the edge — the store
    resolves its bridge through the registry (revision-keyed cache), so the
    very next query serves with the fresh adapter. ARR stays > 0.95.

The runbook documents this flow: docs/upgrade-runbook.md §"Online refits
during migration".
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.ann import FlatIndex, recall_at_k
from repro.core import FitConfig, OnlineAdapterManager, OnlineConfig
from repro.data.drift import MILD_TEXT
from repro.serve import VectorStore
from benchmarks.common import Scale, build_scenario, emit, save_json

TICKS = 24
REFRESH_FRAC = 0.05


def run(scale: Scale) -> dict:
    n = min(scale.n_items, 100_000)
    scen = build_scenario(
        "online", MILD_TEXT, Scale(n_items=n, n_queries=scale.n_queries,
                                   n_pairs=scale.n_pairs),
        corpus_seed=0, pair_seed=5,
    )
    k = 10

    # one store, one lifecycle: the handle owns the migration bitmap and
    # replace_rows mutations; serving reads both through search_mixed
    store = VectorStore(FlatIndex(corpus=scen.corpus_old), version="t0")
    handle = store.upgrade(
        "t1",
        corpus_new_provider=lambda ids: scen.corpus_new[jnp.asarray(ids)],
    )
    fixed = handle.fit(
        scen.pairs_b, scen.pairs_a,
        config=FitConfig(kind="mlp", use_dsm=True),
    )
    handle.deploy()

    # the online arm decorates the SAME registry edge the store serves
    # from: every refit is an atomic edge replacement, picked up by the
    # store's revision-keyed bridge cache on the next query
    mgr = OnlineAdapterManager(
        d_new=scen.pairs_b.shape[1], d_old=scen.pairs_a.shape[1],
        config=OnlineConfig(kind="mlp", max_epochs_per_refit=10),
        registry=store.registry, src="t1", dst="t0",
    )
    mgr.observe_pairs(np.asarray(scen.pairs_b), np.asarray(scen.pairs_a))
    mgr.tick()

    per_refresh = int(n * REFRESH_FRAC)
    history = {"fixed_t0": [], "online": [], "frac_new": []}
    for t in range(1, TICKS + 1):
        # background re-embedder: migrate the next 5 % through the handle
        # (replace_rows + bitmap flip) and emit the fresh ⟨f_new, f_old⟩
        # pairs for exactly the rows the handle reports it migrated
        handle.migrate_batch(per_refresh)
        newly = handle.last_migrated_ids
        if len(newly):
            mgr.observe_pairs(
                np.asarray(scen.corpus_new[jnp.asarray(newly)]),
                np.asarray(scen.corpus_old[jnp.asarray(newly)]),
            )
        mgr.tick()

        # fixed_t0: the frozen adapter against the whole mixed index,
        # blind to the migration bitmap (the pre-mixed-serving failure mode)
        _, ids_fixed = store.index.search_bridged(fixed, scen.q_new, k=k)
        arr_fixed = float(recall_at_k(ids_fixed, scen.gt))

        # online: the store's mixed-state path + the refit-decorated edge
        res = store.search(scen.q_new, k=k)
        assert res.adapter_kind.startswith(
            ("mixed:", "native")
        ), res.adapter_kind
        arr_online = float(recall_at_k(res.ids, scen.gt))

        history["fixed_t0"].append(arr_fixed)
        history["online"].append(arr_online)
        history["frac_new"].append(float(handle.progress))

    out = {
        "history": history,
        "fixed_final": history["fixed_t0"][-1],
        "online_min": min(history["online"]),
        "refits": mgr.refits,
        "lifecycle_stage": handle.stage.value,
        "progress": float(handle.progress),
    }
    emit("online.fixed_t0.final_arr", 0.0, round(out["fixed_final"], 4))
    emit("online.retrained.min_arr", 0.0, round(out["online_min"], 4))
    save_json("online_adaptation", out)
    return out
