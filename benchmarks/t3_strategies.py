"""Table 3 — upgrade-strategy comparison (Full Re-index / Dual Index /
Drift-Adapter) for a 1M-item text database.

Measured here: adapter fit wall-clock, adapter apply latency (CPU measured
µs/query + TPU roofline projection from exact FLOPs), recall (from the T1
AG-News scenario). Modeled (as in the paper, which also estimates these):
re-embedding GPU-hours at a measured-throughput-free reference rate of
1M items ≈ 0.5–1 GPU-hr (A100, d=768 encoder) and HNSW build CPU-hours —
the >100× recompute saving is the ratio of measured adapter-fit seconds to
modeled re-embed hours, and stays >100× under ANY plausible encoder rate.
"""
from __future__ import annotations

import jax

from repro.core import DriftAdapter, FitConfig
from repro.data.drift import MILD_TEXT
from benchmarks.common import (
    Scale, build_scenario, emit, eval_adapter, save_json, time_per_call_us,
)
from repro.launch.roofline import PEAK_FLOPS


def run(scale: Scale) -> dict:
    scen = build_scenario("t3", MILD_TEXT, scale, corpus_seed=0, pair_seed=5)
    adapter = DriftAdapter.fit(
        scen.pairs_b, scen.pairs_a, kind="mlp",
        config=FitConfig(kind="mlp", use_dsm=True),
    )
    quality = eval_adapter(scen, adapter)

    # -- measured apply latency (batch-amortized, the serving configuration)
    apply_jit = jax.jit(lambda q: adapter.apply(q))
    batch = scen.q_new[:256]
    us_per_query_cpu = time_per_call_us(
        apply_jit, batch, per_call_items=batch.shape[0]
    )
    # TPU projection from exact FLOPs (roofline, compute term):
    us_per_query_tpu = adapter.flops_per_query / PEAK_FLOPS * 1e6

    # -- modeled strategy costs (1M items, d=768; same assumptions as paper)
    n_full = 1_000_000
    embed_rate_items_per_gpu_s = 400.0       # ~0.5-1 GPU-hr for 1M items
    reembed_gpu_hours = n_full / embed_rate_items_per_gpu_s / 3600.0
    index_build_cpu_hours = 0.35             # HNSW M=32 efC=200, 1M×768
    adapter_fit_hours = adapter.fit_info.fit_seconds / 3600.0
    recompute_saving = (reembed_gpu_hours + index_build_cpu_hours) / max(
        adapter_fit_hours, 1e-9
    )

    rows = {
        "full_reindex": {
            "r10_arr": 1.0,
            "added_latency_us": 0.0,
            "downtime": f"~{reembed_gpu_hours + index_build_cpu_hours:.1f}-"
                        f"{(reembed_gpu_hours + index_build_cpu_hours) * 2:.1f} hrs",
            "recompute": f"{reembed_gpu_hours:.2f} GPU-hrs + "
                         f"{index_build_cpu_hours:.2f} CPU-hrs",
            "peak_resources": "1x index build capacity",
        },
        "dual_index": {
            "r10_arr": 0.995,           # merge of old+new (paper's estimate)
            "added_latency_us": "50-100 (transition: query both + merge)",
            "downtime": "~0 (gradual shift)",
            "recompute": f"{reembed_gpu_hours:.2f} GPU-hrs + CPU build",
            "peak_resources": "2x serve + build capacity",
        },
        "drift_adapter_mlp": {
            "r10_arr": quality["r10_arr"],
            "added_latency_us_cpu_measured": us_per_query_cpu,
            "added_latency_us_tpu_projected": us_per_query_tpu,
            "downtime": f"~mins (fit {adapter.fit_info.fit_seconds:.1f}s "
                        "+ router rollout)",
            "recompute": f"{adapter.fit_info.fit_seconds:.1f}s adapter fit",
            "peak_resources": "negligible (<3MB per router)",
            "recompute_saving_vs_full": f">{recompute_saving:.0f}x",
        },
    }
    emit("t3.drift_adapter.apply_us_cpu", us_per_query_cpu,
         round(quality["r10_arr"], 4))
    emit("t3.drift_adapter.apply_us_tpu_proj", us_per_query_tpu,
         adapter.flops_per_query)
    emit("t3.drift_adapter.fit_seconds",
         adapter.fit_info.fit_seconds * 1e6, round(recompute_saving))
    save_json("t3_strategies", rows)
    return rows
