"""Table 2 — image upgrade analogue (CLIP ViT-B/32 512-d → ViT-L/14 768-d).

A genuinely rectangular upgrade: the legacy index stores 512-d embeddings,
new queries arrive 768-d; adapters map 768→512 (semi-orthogonal OP,
rectangular LA/MLP with learned residual projection).
"""
from __future__ import annotations

import numpy as np

from repro.data.drift import IMAGE_CLIP
from benchmarks.common import Scale, build_scenario, emit, fit_and_eval, save_json


def run(scale: Scale) -> dict:
    results: dict = {}
    per: dict[str, list] = {"misaligned": [], "op": [], "la": [], "mlp": []}
    fits: dict[str, list] = {"op": [], "la": [], "mlp": []}
    for seed in range(scale.seeds):
        scen = build_scenario(
            "t2_laion", IMAGE_CLIP, scale, corpus_seed=7, pair_seed=50 + seed
        )
        per["misaligned"].append((scen.misaligned_r10, scen.misaligned_mrr))
        for kind, dsm in (("op", False), ("la", True), ("mlp", True)):
            r = fit_and_eval(scen, kind, use_dsm=dsm, seed=seed)
            per[kind].append((r["r10_arr"], r["mrr_arr"]))
            fits[kind].append(r["fit_seconds"])
    for method, vals in per.items():
        arr = np.asarray(vals)
        results[method] = {
            "r10_arr_mean": float(arr[:, 0].mean()),
            "r10_arr_std": float(arr[:, 0].std()),
            "mrr_arr_mean": float(arr[:, 1].mean()),
            "mrr_arr_std": float(arr[:, 1].std()),
        }
        emit(
            f"t2.laion_clip.{method}.r10_arr",
            0.0 if method == "misaligned" else float(np.mean(fits[method])) * 1e6,
            round(results[method]["r10_arr_mean"], 4),
        )
    save_json("t2_image", results)
    return results
