"""Mixed read/write open-loop workload for the mutable index → BENCH_stream.json.

The streaming counterpart of ``serving_load``: one Poisson arrival process
carries BOTH reads and writes (every 4th event is an insert/delete/upsert,
cycled) into one :class:`FrontDoor`, so mutations ride the scheduler's
write lane and serialize against each drain's reads without ever blocking
read coalescing. A Python-side value model (id → the exact row the store
must serve) mirrors every applied write; the phases gate on it:

* **mixed load** (hard): zero silent drops (offered == completed +
  rejected), every write ticket applied without error, and write
  throughput recorded; latencies are interpret-advisory.
* **post-load parity** (hard): after the stream drains, a probe batch must
  be BIT-IDENTICAL to the brute-force fp32 re-scan of the model — wrong
  values, slots, or liveness bits all diverge here.
* **binary shadow parity** (hard): every applied write is mirrored into a
  second ``precision="binary"`` store, so each mutation re-encodes the
  packed sign-bit plane under load; after the stream drains, EVERY live
  row's packed code must equal ``binarize_rows`` of the value model's row
  (codes can never go stale), and self-retrieval probes — recently
  mutated rows served back through the 2-launch binary scan + exact
  rescore — must return themselves at rank 1 with score ≈ 1.
* **mid-stream compaction** (hard): a ``compact()`` queued on the write
  lane must renumber ids, reject the reads queued behind it explicitly as
  ``stale_revision`` (never serve renumbered ids silently), and the
  re-scan after the id remap must hold recall parity ≥ 0.99 vs the model
  (measured bit-exact).
* **occupancy/tombstone stats**: ``write_stats`` before/after compaction
  — the telemetry gauge the auto-compaction trigger reads.

    PYTHONPATH=src python -m benchmarks.streaming_writes --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.ann import FlatIndex, recall_at_k
from repro.kernels.mixed_scan.ref import masked_topk_scan
from repro.serve import FrontDoor, VectorStore

WRITE_EVERY = 4                      # every 4th event mutates
WRITE_KINDS = ("insert", "delete", "upsert")


def _unit(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def build_world(items: int, dim: int, n_queries: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    corpus = _unit(rng.standard_normal((items, dim)).astype(np.float32))
    queries = _unit(rng.standard_normal((n_queries, dim)).astype(np.float32))
    store = VectorStore(
        FlatIndex(corpus=jnp.asarray(corpus), backend="fused"),
        version="v1",
    )
    store.attach_telemetry()
    # binary shadow: same rows served through the packed sign-bit tier at
    # the default 4·k shortlist (the gate is code-plane sync + exact
    # self-retrieval, both shortlist-independent)
    shadow = VectorStore(
        FlatIndex(corpus=jnp.asarray(corpus), backend="fused"),
        version="v1", precision="binary",
    )
    model = {i: corpus[i] for i in range(items)}
    return rng, store, shadow, model, queries


def oracle_search(model: dict, size: int, dim: int, queries, k: int):
    """Brute-force fp32 re-scan of the value model (the jnp reference the
    kernels are bit-tested against in tests/test_streaming.py)."""
    buf = np.zeros((size, dim), np.float32)
    keep = np.zeros(size, bool)
    for i, r in model.items():
        buf[i], keep[i] = r, True
    return masked_topk_scan(
        jnp.asarray(queries), jnp.asarray(buf), jnp.asarray(keep), k
    )


def apply_write_result(model: dict, kind: str, ticket, payload,
                       shadow=None) -> None:
    """Mirror one applied write ticket into the value model (and, when
    given, the binary shadow store — upserting at the ticket's assigned
    ids keeps both stores' id spaces aligned while re-encoding the
    shadow's packed sign-bit plane on every write)."""
    if ticket.error is not None:
        raise SystemExit(f"stream gate: {kind} write failed: {ticket.error}")
    if kind == "insert":
        ids = np.asarray(ticket.result).tolist()
        for j, r in zip(ids, payload):
            model[int(j)] = r
        if shadow is not None:
            shadow.upsert(ids, jnp.asarray(np.stack(payload)))
    elif kind == "delete":
        for j in payload:
            model.pop(int(j), None)
        if shadow is not None:
            shadow.delete(payload)
    else:
        ids, rows = payload
        for j, r in zip(ids, rows):
            model[int(j)] = r
        if shadow is not None:
            shadow.upsert(ids, jnp.asarray(np.stack(rows)))


def run_mixed_open_loop(
    door, store, model, queries, n_events: int, rate: float, k: int,
    rng, dim: int, shadow=None,
) -> dict:
    """One open-loop arm: Poisson arrivals, every WRITE_EVERY-th event a
    mutation on the write lane, the rest coalesced reads."""
    arrivals = rng.exponential(1.0 / rate, n_events).cumsum()
    pending_writes: list[tuple[str, object, object]] = []
    write_count = {kind: 0 for kind in WRITE_KINDS}
    t0 = time.perf_counter()
    i = 0
    while i < n_events or door.depth > 0 or pending_writes:
        now = time.perf_counter() - t0
        while i < n_events and arrivals[i] <= now:
            if i % WRITE_EVERY == 0:
                kind = WRITE_KINDS[(i // WRITE_EVERY) % len(WRITE_KINDS)]
                live = sorted(model)
                if kind == "insert" or len(live) < 2 * k:
                    rows = _unit(
                        rng.standard_normal((2, dim)).astype(np.float32)
                    )
                    pending_writes.append(
                        ("insert", door.insert(rows), rows)
                    )
                    write_count["insert"] += 1
                elif kind == "delete":
                    ids = rng.choice(live, size=2, replace=False).tolist()
                    pending_writes.append(("delete", door.delete(ids), ids))
                    write_count["delete"] += 1
                else:
                    ids = rng.choice(live, size=2, replace=False).tolist()
                    rows = _unit(
                        rng.standard_normal((2, dim)).astype(np.float32)
                    )
                    pending_writes.append(
                        ("upsert", door.upsert(ids, rows), (ids, rows))
                    )
                    write_count["upsert"] += 1
            else:
                q = queries[i % queries.shape[0]]
                door.submit(q, k=k, now=t0 + arrivals[i])
            i += 1
        if door.depth or pending_writes:
            door.drain()
            # every queued write ran at the head of that drain
            for kind, ticket, payload in pending_writes:
                apply_write_result(model, kind, ticket, payload,
                                   shadow=shadow)
            pending_writes.clear()
        elif i < n_events:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.01))
    duration = time.perf_counter() - t0
    rollup = door.slo_rollup()
    writes_total = sum(write_count.values())
    rollup.update({
        "duration_s": duration,
        "writes": write_count,
        "writes_total": writes_total,
        "write_throughput_rps": writes_total / duration,
        "offered_rate": rate,
    })
    return rollup


def run_parity_probe(store, model, queries, k: int) -> dict:
    """Hard gate: served results == the model's brute-force re-scan."""
    s_ref, i_ref = oracle_search(
        model, int(store.index.size), int(store.index.dim), queries, k
    )
    res = store.search(jnp.asarray(queries), k=k)
    ids_ok = bool(np.array_equal(np.asarray(res.ids), np.asarray(i_ref)))
    scores_ok = bool(np.allclose(
        np.asarray(res.scores), np.asarray(s_ref), atol=1e-5
    ))
    return {
        "checked": int(queries.shape[0]),
        "bit_identical": ids_ok and scores_ok,
        "recall_vs_model": float(recall_at_k(res.ids, i_ref)),
    }


def run_binary_parity(shadow, model, k: int, probes: int = 8) -> dict:
    """Hard gates on the mutated binary shadow:

    1. **Code-plane sync** — every live row's packed word row equals
       ``binarize_rows`` of the value model's row (a write that skipped
       the re-encode diverges here; pure host math, zero launches).
    2. **Exact self-retrieval** — the highest-id live rows (the stream's
       freshest inserts/upserts) served back as queries through the
       binary scan + exact rescore return THEMSELVES at rank 1 with
       score ≈ 1 (unit rows: self-dot = 1, self-hamming = 0, so rank 1
       is exact at any shortlist width).
    """
    from repro.kernels.engine.ops import binarize_rows

    live = sorted(model)
    rows = np.stack([model[i] for i in live])
    want = np.asarray(binarize_rows(jnp.asarray(rows)))
    got = np.asarray(shadow.index.bin_codes)[np.asarray(live)]
    codes_ok = bool(np.array_equal(want, got))

    probe_ids = live[-probes:]
    res = shadow.search(
        jnp.asarray(np.stack([model[i] for i in probe_ids])), k=k
    )
    top_ids = np.asarray(res.ids)[:, 0]
    top_scores = np.asarray(res.scores)[:, 0]
    self_ok = bool(
        np.array_equal(top_ids, np.asarray(probe_ids))
        and np.allclose(top_scores, 1.0, atol=1e-5)
    )
    return {
        "live_rows_checked": len(live),
        "self_probes": len(probe_ids),
        "precision": shadow.precision,
        "binarized": bool(getattr(shadow.index, "binarized", False)),
        "codes_in_sync": codes_ok,
        "self_retrieval_exact": self_ok,
        "bit_identical": codes_ok and self_ok,
    }


def run_compaction_phase(door, store, model, queries, k: int) -> dict:
    """Queue compact() on the write lane with reads behind it: the stale
    reads must be rejected explicitly, ids renumber, parity must hold."""
    # guarantee real tombstones going in (the mixed stream's inserts may
    # have refilled every slot its deletes freed)
    doomed = sorted(model)[: max(2, len(model) // 10)]
    drop = door.delete(doomed)
    door.drain()
    apply_write_result(model, "delete", drop, doomed)

    stats_before = store.write_stats()
    ticket = door.compact()
    stale_reads = [door.submit(q, k=k) for q in queries[:8]]
    summary = door.drain()
    if ticket.error is not None:
        raise SystemExit(f"stream gate: compact failed: {ticket.error}")
    kept = np.asarray(ticket.result)
    remap = {int(o): n for n, o in enumerate(kept)}
    renumbered = {remap[i]: r for i, r in model.items()}
    model.clear()
    model.update(renumbered)
    stale_rejected = sum(
        1 for r in stale_reads
        if not r.result.ok and r.result.reason == "stale_revision"
    )
    # the rejected reads resubmit cleanly against the new revision
    retry = [door.submit(q, k=k) for q in queries[:8]]
    door.drain()
    parity = run_parity_probe(store, model, queries, k)
    return {
        "tombstone_ratio_before": stats_before["tombstone_ratio"],
        "capacity_before": stats_before["capacity"],
        "capacity_after": store.write_stats()["capacity"],
        "index_revision": store.index_revision,
        "stale_rejected": stale_rejected,
        "drain_stale": summary["stale"],
        "retries_ok": all(r.result.ok for r in retry),
        "recall_parity": parity["recall_vs_model"],
        "bit_identical": parity["bit_identical"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2k items, dim 64, short stream")
    ap.add_argument("--items", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--events", type=int, default=None,
                    help="arrivals in the mixed read/write stream")
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    items = args.items or (2_000 if args.smoke else 20_000)
    dim = args.dim or (64 if args.smoke else 256)
    n_events = args.events or (240 if args.smoke else 800)

    rng, store, shadow, model, queries = build_world(
        items, dim, n_queries=32
    )
    door = FrontDoor(store, max_depth=16 * n_events)

    # capacity probe (also warms the serving plan trace)
    t0 = time.perf_counter()
    store.search(jnp.asarray(queries), k=args.k)
    capacity = max(32.0, 32.0 / (time.perf_counter() - t0))

    load = run_mixed_open_loop(
        door, store, model, queries, n_events=n_events,
        rate=capacity, k=args.k, rng=rng, dim=dim, shadow=shadow,
    )
    emit("stream_mixed_load", load["total_p50_ms"] * 1e3,
         load["write_throughput_rps"])
    print(f"# load: writes={load['writes_total']} "
          f"({load['write_throughput_rps']:.0f}/s) "
          f"reads_completed={load['completed']} "
          f"p50={load['total_p50_ms']:.1f}ms "
          f"conservation_ok={load['conservation_ok']}")

    parity = run_parity_probe(store, model, queries, args.k)
    emit("stream_parity", 0.0, parity["recall_vs_model"])
    print(f"# parity: bit_identical={parity['bit_identical']} "
          f"recall={parity['recall_vs_model']:.3f}")

    binary = run_binary_parity(shadow, model, args.k)
    emit("stream_binary_parity", 0.0,
         float(binary["bit_identical"]))
    print(f"# binary shadow: codes_in_sync={binary['codes_in_sync']} "
          f"self_retrieval_exact={binary['self_retrieval_exact']} "
          f"({binary['live_rows_checked']} rows)")

    # compaction renumbers the main store's ids only — the shadow's gate
    # is complete, so it stops mirroring here
    compaction = run_compaction_phase(door, store, model, queries, args.k)
    emit("stream_compaction", 0.0, compaction["recall_parity"])
    print(f"# compaction: ratio_before="
          f"{compaction['tombstone_ratio_before']:.3f} "
          f"stale_rejected={compaction['stale_rejected']} "
          f"recall_parity={compaction['recall_parity']:.3f}")

    save_json("BENCH_stream", {
        "config": {
            "items": items, "dim": dim, "events": n_events, "k": args.k,
            "write_every": WRITE_EVERY,
            "capacity_probe_rps": capacity,
            "platform": jax.default_backend(),
        },
        "caveat": (
            "CPU interpret-mode latencies; re-measure on real TPU"
            if jax.default_backend() == "cpu" else ""
        ),
        "load": load,
        "parity": parity,
        "binary_parity": binary,
        "compaction": compaction,
        "write_stats": store.write_stats(),
        "telemetry": store.telemetry.counters(),
    })
    print("wrote BENCH_stream.json")

    # the benchmark's own hard gates (CI re-asserts via check_bench)
    if not load["conservation_ok"]:
        raise SystemExit("stream gate: mixed arm dropped requests silently")
    if load["writes_total"] < 1 or load["write_throughput_rps"] <= 0:
        raise SystemExit("stream gate: no writes applied")
    if not parity["bit_identical"]:
        raise SystemExit(
            "stream gate: post-load serving diverged from the value model"
        )
    if not binary["bit_identical"]:
        raise SystemExit(
            "stream gate: binary shadow diverged from the value model "
            "after mutations re-encoded its packed sign-bit plane"
        )
    if compaction["stale_rejected"] < 1:
        raise SystemExit(
            "stream gate: reads queued behind compact() were not "
            "rejected as stale_revision"
        )
    if compaction["recall_parity"] < 0.99:
        raise SystemExit(
            f"stream gate: post-compaction recall parity "
            f"{compaction['recall_parity']:.3f} < 0.99"
        )
    if not compaction["retries_ok"]:
        raise SystemExit("stream gate: post-compaction resubmits failed")


if __name__ == "__main__":
    main()
