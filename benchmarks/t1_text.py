"""Table 1 — text upgrades (MiniLM→MPNet analogue) on three corpora.

Three synthetic corpora mirror AG-News / DBpedia-14 / Emotion: same d=768
upgrade family, drift severity calibrated so the Misaligned baseline spans
the paper's observed spread (0.589–0.723 R@10 ARR). Protocol follows §4:
OP without DSM, LA(r=64)/MLP(256) with DSM, N_p=20k pairs, mean±std over
seeds when --seeds > 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.drift import MILD_TEXT
from benchmarks.common import (
    Scale, build_scenario, emit, fit_and_eval, save_json,
)

DATASETS = {
    # name: (rotation_theta, corpus_seed) — severity mirrors the paper's
    # per-dataset misaligned spread
    "agnews": (0.30, 0),
    "dbpedia": (0.34, 1),
    "emotion": (0.25, 2),
}


def run(scale: Scale) -> dict:
    results: dict = {}
    for ds, (theta, cseed) in DATASETS.items():
        dcfg = dataclasses.replace(MILD_TEXT, rotation_theta=theta,
                                   seed=MILD_TEXT.seed + cseed)
        per_seed: dict[str, list] = {
            "misaligned": [], "op": [], "la": [], "mlp": []
        }
        fit_secs: dict[str, list] = {"op": [], "la": [], "mlp": []}
        for seed in range(scale.seeds):
            scen = build_scenario(
                f"t1_{ds}", dcfg, scale,
                corpus_seed=cseed, pair_seed=5 + seed,
            )
            per_seed["misaligned"].append(
                (scen.misaligned_r10, scen.misaligned_mrr)
            )
            for kind, dsm in (("op", False), ("la", True), ("mlp", True)):
                r = fit_and_eval(scen, kind, use_dsm=dsm, seed=seed)
                per_seed[kind].append((r["r10_arr"], r["mrr_arr"]))
                fit_secs[kind].append(r["fit_seconds"])
        ds_out = {}
        for method, vals in per_seed.items():
            arr = np.asarray(vals)
            ds_out[method] = {
                "r10_arr_mean": float(arr[:, 0].mean()),
                "r10_arr_std": float(arr[:, 0].std()),
                "mrr_arr_mean": float(arr[:, 1].mean()),
                "mrr_arr_std": float(arr[:, 1].std()),
            }
            if method in fit_secs:
                ds_out[method]["fit_seconds"] = float(
                    np.mean(fit_secs[method])
                )
            emit(
                f"t1.{ds}.{method}.r10_arr",
                0.0 if method == "misaligned"
                else float(np.mean(fit_secs[method])) * 1e6,
                round(ds_out[method]["r10_arr_mean"], 4),
            )
        results[ds] = ds_out
    save_json("t1_text", results)
    return results
