"""Shared benchmark scaffolding: scenario setup, search, timing, reporting.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (the harness
contract) and dumps a JSON artifact under experiments/bench/.

Scale note (EXPERIMENTS.md §Calibration): the paper runs 1M items × 10k
queries on Xeon + A100; this container is one CPU core, so the default
scale is 100k items × 1k queries (--full restores 1M×10k, --quick drops to
30k×500). ARR is scale-stable: it is a *ratio* of recalls on the same
corpus, and we verified (§Calibration) it moves <0.01 between 30k and 200k.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.ann import flat_search_jnp, mrr, recall_at_k
from repro.core import DriftAdapter, FitConfig
from repro.data import (
    CorpusConfig,
    DriftConfig,
    make_corpus,
    make_drift,
    make_pairs,
    make_queries,
)

BENCH_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/bench")


@dataclasses.dataclass
class Scale:
    n_items: int = 100_000
    n_queries: int = 1_000
    n_pairs: int = 20_000
    seeds: int = 1


QUICK = Scale(n_items=30_000, n_queries=500, n_pairs=20_000, seeds=1)
DEFAULT = Scale()
FULL = Scale(n_items=1_000_000, n_queries=10_000, n_pairs=20_000, seeds=5)


@dataclasses.dataclass
class Scenario:
    """One drift scenario: legacy corpus + drifted space + query sets."""

    name: str
    corpus_old: jax.Array
    corpus_new: jax.Array
    q_new: jax.Array
    gt: jax.Array            # oracle top-10 ids (new space, exhaustive)
    gt_top1: jax.Array
    pairs_b: jax.Array
    pairs_a: jax.Array
    misaligned_r10: float
    misaligned_mrr: float


def build_scenario(
    name: str,
    drift_cfg: DriftConfig,
    scale: Scale,
    *,
    corpus_seed: int = 0,
    pair_seed: int = 5,
    k: int = 10,
    corpus_cfg: Optional[CorpusConfig] = None,
) -> Scenario:
    ccfg = corpus_cfg or CorpusConfig(
        n_items=scale.n_items,
        dim=drift_cfg.d_old,
        n_clusters=max(200, scale.n_items // 150),
        concentration=0.4,
        spectrum_beta=1.0,
        seed=corpus_seed,
    )
    corpus_old, _ = make_corpus(ccfg)
    drift = make_drift(drift_cfg)
    corpus_new = drift(corpus_old, noise_salt=0)
    q_old, _ = make_queries(ccfg, scale.n_queries)
    q_new = drift(q_old, noise_salt=1)
    _, gt = flat_search_jnp(corpus_new, q_new, k=k)
    # Misaligned baseline for rectangular upgrades (paper §5.3): the shorter
    # side is zero-padded to the longer one (GloVe-300 padded to MPNet-768).
    d_old, d_new = corpus_old.shape[1], q_new.shape[1]
    if d_old == d_new:
        mis_corpus, mis_q = corpus_old, q_new
    else:
        d = max(d_old, d_new)
        mis_corpus = jnp.pad(corpus_old, ((0, 0), (0, d - d_old)))
        mis_q = jnp.pad(q_new, ((0, 0), (0, d - d_new)))
    _, mis = flat_search_jnp(mis_corpus, mis_q, k=k)
    pairs_b, pairs_a, _ = make_pairs(
        jax.random.PRNGKey(pair_seed), corpus_old, corpus_new, scale.n_pairs
    )
    return Scenario(
        name=name,
        corpus_old=corpus_old,
        corpus_new=corpus_new,
        q_new=q_new,
        gt=gt,
        gt_top1=gt[:, 0],
        pairs_b=pairs_b,
        pairs_a=pairs_a,
        misaligned_r10=float(recall_at_k(mis, gt)),
        misaligned_mrr=float(mrr(mis, gt[:, 0])),
    )


def eval_adapter(
    scen: Scenario, adapter: DriftAdapter, k: int = 10
) -> dict:
    """Search the LEGACY index with adapted queries; score against oracle."""
    q_mapped = adapter.apply(scen.q_new)
    _, ids = flat_search_jnp(scen.corpus_old, q_mapped, k=k)
    return {
        "r10_arr": float(recall_at_k(ids, scen.gt)),
        "mrr_arr": float(mrr(ids, scen.gt_top1)),
    }


def fit_and_eval(
    scen: Scenario, kind: str, *, use_dsm: bool, seed: int = 0,
    config: Optional[FitConfig] = None,
) -> dict:
    cfg = config or FitConfig(kind=kind, use_dsm=use_dsm, seed=seed)
    adapter = DriftAdapter.fit(
        scen.pairs_b, scen.pairs_a, kind=kind, config=cfg
    )
    out = eval_adapter(scen, adapter)
    out["fit_seconds"] = adapter.fit_info.fit_seconds
    out["epochs"] = adapter.fit_info.epochs_run
    out["val_mse"] = adapter.fit_info.val_mse
    out["param_bytes"] = adapter.param_bytes
    out["flops_per_query"] = adapter.flops_per_query
    return out


def time_per_call_us(fn: Callable, *args, warmup: int = 2, iters: int = 10,
                     per_call_items: int = 1) -> float:
    """Wall-clock µs per call (per item if per_call_items > 1)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6 / per_call_items


def emit(name: str, us_per_call: float, derived) -> None:
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def save_json(name: str, payload: dict) -> None:
    from repro.kernels.common import is_cpu

    # every artifact records HOW its kernels ran: check_bench downgrades
    # speedup-floor gates to advisories when interpret_mode is true (CPU
    # interpret-mode ratios are artifacts, cf. BENCH_ivf's 0.402)
    payload.setdefault("interpret_mode", bool(is_cpu()))
    os.makedirs(BENCH_DIR, exist_ok=True)
    with open(os.path.join(BENCH_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=str)
