"""Figure 1 — R@10 ARR vs number of training pairs N_p (MLP + DSM, AG-News
analogue). Expected signature: steep rise 1k→5k, plateau by 16k ≈ 20k."""
from __future__ import annotations


from repro.core import DriftAdapter, FitConfig
from repro.data.drift import MILD_TEXT
from benchmarks.common import Scale, build_scenario, emit, eval_adapter, save_json

N_P_GRID = (1_000, 2_000, 5_000, 10_000, 16_000, 20_000)


def run(scale: Scale) -> dict:
    scen = build_scenario(
        "fig1", MILD_TEXT, scale, corpus_seed=0, pair_seed=5
    )
    out = {}
    for n_p in N_P_GRID:
        b = scen.pairs_b[:n_p]
        a = scen.pairs_a[:n_p]
        ad = DriftAdapter.fit(
            b, a, kind="mlp", config=FitConfig(kind="mlp", use_dsm=True)
        )
        r = eval_adapter(scen, ad)
        out[str(n_p)] = {**r, "fit_seconds": ad.fit_info.fit_seconds}
        emit(f"fig1.np_{n_p}.r10_arr", ad.fit_info.fit_seconds * 1e6,
             round(r["r10_arr"], 4))
    save_json("fig1_training_size", out)
    return out
